"""Two-level KV cache: a hot device ring + paged, incrementally-staged
cold host history.

DESIGN.md §2a — the paper's architecture one level down the hierarchy:
*device HBM* plays Tachyon (small, memory-speed, holds the hot working
set), *host DRAM* plays OrangeFS (large, slower, holds everything).  The
paper's Eq. 7 blended read applies with ``f = hot_len / total_len`` and
rates (HBM bw, PCIe bw); its read mode (f) — nearest copy first, fall
through to the big tier — is the decode path here.

The cold tier is **paged** (the L2 analogue of ``core/layout.py``
blocks): fixed-size pages of ``page`` tokens, page-aligned at the
hot/cold boundary.  Because decode history is append-only, a completed
page is immutable — it is uploaded host→device **exactly once** into a
device-resident staging buffer and reused by every later step.  Per-step
staged H2D bytes are therefore O(page) amortized O(1), not O(history):
the fix for the seed's restage-the-whole-prefix-per-step O(T²) decode
path (the "re-read the whole file from the slow tier per request"
anti-pattern the two-level design exists to eliminate).

Semantics:
* ``append(k, v)`` writes the newest token into the hot ring (device)
  and queues it for **batched** host write-through — no device→host sync
  per token; pending tokens are flushed in one transfer when a page
  completes (or on ``flush_host()``).  This is the paper's write mode
  (c) with a bounded async window (≤ ~2 pages of tokens), the same
  durability trade as the store's ASYNC_WRITEBACK flush pipeline.
* ``stage_cold()`` uploads newly completed cold pages to the device
  staging buffer (dispatch it before ``attend`` so the H2D DMA overlaps
  compute; jax dispatch is async).  The staging buffer grows by doubling
  — O(log T) reallocations / retraces over a whole decode, never per
  step.  With ``page <= window`` every page is complete before the first
  step that needs it, so the partial tail page is never re-uploaded; the
  capacity tail past ``cold_len`` is masked inside the kernel.
* ``attend(q)`` runs the ring-aware tiered decode kernel over both tiers
  with *dynamic* lengths — one compiled kernel for the whole decode, no
  per-step chronological gather of the ring, no per-step ``jnp.pad`` of
  the history, and no per-step dummy allocation when the cold tier is
  empty (the capacity buffer always exists; ``cold_len=0`` masks it).
* ``host_views()`` returns the flushed history as numpy views;
  ``rebuild_hot_from_cold()`` is the fault-tolerance path.
* Optional third level: constructed with a ``store=`` (a
  :class:`~repro.core.store.TwoLevelStore`), every *completed* cold page
  is also persisted into the store (async write-back) under
  ``<store_prefix>/<name>/page_NNNNNN`` — the host tier declares itself
  to the adaptive I/O controller as a **latency-sensitive** stream
  (``StreamClass.LATENCY``: always admitted, never flush-dropped,
  minimum readahead), and ``restore_cold_from_store()`` rebuilds the
  history up to the last persisted page after *host* DRAM loss — one
  more rung of the paper's re-read-from-the-durable-tier story.

The host tier is stored in the cache dtype (bf16 via ``ml_dtypes``), not
hard-coded float32 — half the ``host_bytes`` of the seed layout.  The
capacity story mirrors the paper: hot-ring budget = O(window); the
staging buffer converges to the full cold history in device memory (the
win is *bandwidth* — each page crosses PCIe once), host budget =
O(max_len) for durability and device-loss recovery.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TieredKVStats:
    appended: int = 0
    hot_hits_tokens: int = 0
    cold_reads_tokens: int = 0
    bytes_staged: int = 0  # host->device page uploads (each page once)
    pages_staged: int = 0
    bytes_written_through: int = 0  # device->host write-through traffic
    d2h_flushes: int = 0  # batched sync points (seed path: one per token)
    pages_persisted: int = 0  # completed pages written into the store tier
    bytes_persisted: int = 0
    evictions: int = 0  # full evict-to-store cycles (idle session parked)
    resumes: int = 0  # full resume-from-store cycles
    demotions: int = 0  # staging-buffer drops under arbiter pressure

    def hot_fraction(self) -> float:
        """The paper's f = hot / (hot + cold) over all attends so far."""
        total = self.hot_hits_tokens + self.cold_reads_tokens
        return self.hot_hits_tokens / total if total else 1.0


class SharedPageRegistry:
    """Content-addressed, refcounted cold-page table over one store.

    DESIGN.md §14: sessions sharing a prompt prefix produce bit-identical
    completed cold pages (causal attention ⇒ k/v at position *i* depend
    only on tokens ≤ *i*, and the host tier stores the cache dtype
    exactly), so pages are keyed by content hash and stored **once**
    across every session and tier.  ``put`` takes a reference (storing the
    blob on first sight), ``decref`` drops one and deletes the blob when
    the count reaches zero — a retiring session can never free a page
    another live session still maps.  Counters are cumulative so the
    dedup ratio survives sessions retiring.
    """

    def __init__(self, store, prefix: str = "serving/pages") -> None:
        from repro.core.sched import StreamClass

        self.store = store
        self.prefix = prefix
        self._lock = threading.Lock()
        self._refs: dict[str, int] = {}
        self.pages_logical = 0  # references handed out (puts + adopts)
        self.pages_stored = 0  # distinct blobs ever written to the store
        self.dedup_hits = 0
        store.hint_stream(prefix + "/", StreamClass.LATENCY)

    def _file(self, key: str) -> str:
        return f"{self.prefix}/{key}"

    def put(self, blob: bytes) -> str:
        """Intern a completed page; returns its content key (ref held)."""
        key = hashlib.sha1(blob).hexdigest()
        with self._lock:
            self.pages_logical += 1
            n = self._refs.get(key, 0)
            self._refs[key] = n + 1
            if n:
                self.dedup_hits += 1
                return key
            self.pages_stored += 1
        from repro.core.store import WriteMode

        self.store.put(self._file(key), blob, mode=WriteMode.ASYNC_WRITEBACK)
        return key

    def fetch(self, key: str) -> bytes:
        return self.store.get(self._file(key))

    def adopt(self, keys) -> None:
        """Take references on already-stored pages — the resume path after
        the registry's in-memory refcounts were lost (host restart): the
        blobs are durable in the store, only the counts need rebuilding."""
        with self._lock:
            for key in keys:
                self.pages_logical += 1
                n = self._refs.get(key, 0)
                self._refs[key] = n + 1
                if n:
                    self.dedup_hits += 1

    def decref(self, key: str) -> bool:
        """Drop one reference; deletes the blob at zero.  Returns whether
        the physical page was deleted."""
        with self._lock:
            n = self._refs.get(key, 0) - 1
            if n > 0:
                self._refs[key] = n
                return False
            self._refs.pop(key, None)
        self.store.delete(self._file(key))
        return True

    def refcount(self, key: str) -> int:
        with self._lock:
            return self._refs.get(key, 0)

    def live_pages(self) -> int:
        with self._lock:
            return len(self._refs)

    def dedup_ratio(self) -> float:
        """Logical page references per physical stored page (≥ 1)."""
        return self.pages_logical / self.pages_stored if self.pages_stored else 1.0


class TieredKVCache:
    """Per-layer two-level KV cache for one decoding batch.

    Shapes: k, v tokens are (B, KV, D). Hot ring: (B, KV, W, D) on device.
    Cold store: host numpy (B, KV, T_max, D) in the cache dtype, written
    through in batches; staged to device in immutable ``page``-token pages.
    """

    def __init__(
        self,
        batch: int,
        kv_heads: int,
        head_dim: int,
        window: int,
        max_len: int,
        dtype=jnp.bfloat16,
        page: int | None = None,
        store=None,
        store_prefix: str = "serving/kv",
        name: str = "kv0",
        pages: SharedPageRegistry | None = None,
    ):
        if window <= 0 or max_len < window:
            raise ValueError("need 0 < window <= max_len")
        page = min(window, 512) if page is None else page
        if not 0 < page <= window:
            # page <= window guarantees a cold page is complete (and
            # flushable) before the first token it holds leaves the ring.
            raise ValueError("need 0 < page <= window")
        self.batch, self.kv, self.dim = batch, kv_heads, head_dim
        self.window, self.max_len, self.page = window, max_len, page
        self.dtype = dtype
        self.hot_k = jnp.zeros((batch, kv_heads, window, head_dim), dtype)
        self.hot_v = jnp.zeros((batch, kv_heads, window, head_dim), dtype)
        # host tier (the 'OrangeFS' of the pair): full history, numpy, in
        # the cache dtype (ml_dtypes handles bf16) — not fp32.
        host_dt = np.dtype(jnp.dtype(dtype))
        self.cold_k = np.zeros((batch, kv_heads, max_len, head_dim), host_dt)
        self.cold_v = np.zeros((batch, kv_heads, max_len, head_dim), host_dt)
        # device staging buffer: paged capacity, grown by doubling.  The
        # kernel streams it in sublane-aligned blocks, so capacity is kept
        # a _block_k multiple — serving never hits the kernel's pad path.
        self._block_k = page if page % 8 == 0 else 8 * (-(-page // 8))
        self._cap = self._block_k
        self._cold_k_dev = jnp.zeros((batch, kv_heads, self._cap, head_dim), dtype)
        self._cold_v_dev = jnp.zeros_like(self._cold_k_dev)
        self._staged_pages = 0  # completed pages valid in the staging buffer
        self._pending_k: list[jax.Array] = []  # (B, KV, n, D) blocks awaiting
        self._pending_v: list[jax.Array] = []  # batched host write-through
        self._flushed = 0  # tokens durably on the host tier
        self.length = 0
        self.stats = TieredKVStats()
        # Optional store-backed third level (TwoLevelStore), with the host
        # tier declared latency-sensitive to the adaptive I/O controller.
        # With a SharedPageRegistry, completed pages are content-addressed
        # and refcounted (shared across sessions); tail + manifest stay
        # private under this cache's own store directory.
        if pages is not None and store is None:
            store = pages.store
        self._store = store
        self._store_dir = f"{store_prefix}/{name}"
        self._persisted_pages = 0
        self._pages = pages
        self._page_keys: list[str] = []
        self._arb_pool = None
        self._closed = False
        if store is not None:
            from repro.core.sched import StreamClass

            store.hint_stream(store_prefix + "/", StreamClass.LATENCY)

    def attach_arbiter(self, arbiter, min_bytes: int = 0, weight: float = 1.0,
                       name: str = "kv_staging"):
        """Register the host KV history as pool ``name`` (LATENCY) of an
        elastic :class:`~repro.core.arbiter.MemoryArbiter`.

        The pool floors to live usage (``floor_to_usage``): decode
        correctness needs every appended token's host copy, so the arbiter
        may route *idle* headroom elsewhere but can never ask this pool to
        shed held bytes.  Usage grows with decoded length; demand is the
        full ``max_len`` history the buffers were provisioned for.  The
        handle is kept so :meth:`close` deregisters it — a retired session
        must return its bytes to the pot, not strand them.
        """
        per_token = (
            2 * self.batch * self.kv * self.dim * self.cold_k.dtype.itemsize
        )
        pool = arbiter.register(
            name,
            cls="latency",
            min_bytes=min_bytes,
            weight=weight,
            initial_bytes=per_token * self.max_len,
            floor_to_usage=True,
        )

        def value_fn() -> float:
            pool.note_used(per_token * self.length)
            pool.note_demand(per_token * self.max_len)
            return 16.0 * weight

        pool.value_fn = value_fn
        self._arb_pool = pool
        return pool

    # ------------------------------------------------------- store offload

    def _page_file(self, p: int) -> str:
        return f"{self._store_dir}/page_{p:06d}"

    def _tail_file(self) -> str:
        return f"{self._store_dir}/tail"

    def _manifest_file(self) -> str:
        return f"{self._store_dir}/manifest"

    def _write_manifest(self, tail: int = 0) -> None:
        """Persist the session's page map: ordered content keys (registry
        mode), page geometry, and — after an eviction — the tail length so
        a resume restores the *exact* logical length, not just the durable
        page boundary."""
        from repro.core.store import WriteMode

        man: dict = {
            "page": self.page,
            "pages": self._persisted_pages,
            "length": self.length,
            "tail": tail,
        }
        if self._pages is not None:
            man["keys"] = self._page_keys
        self._store.put(
            self._manifest_file(), json.dumps(man).encode(),
            mode=WriteMode.ASYNC_WRITEBACK,
        )

    def _read_manifest(self) -> dict:
        if self._store.exists(self._manifest_file()):
            return json.loads(self._store.get(self._manifest_file()))
        return {}

    def _persist_pages(self) -> None:
        """Write newly completed (immutable) cold pages into the store —
        each exactly once, k bytes then v bytes, async write-back.  With a
        :class:`SharedPageRegistry` the page is interned by content hash
        (shared prompt prefixes across sessions store one copy); otherwise
        it lands under this cache's private ``page_NNNNNN`` name."""
        from repro.core.store import WriteMode

        full = self._flushed // self.page
        new = full > self._persisted_pages
        for p in range(self._persisted_pages, full):
            lo, hi = p * self.page, (p + 1) * self.page
            blob = (
                np.ascontiguousarray(self.cold_k[:, :, lo:hi, :]).tobytes()
                + np.ascontiguousarray(self.cold_v[:, :, lo:hi, :]).tobytes()
            )
            if self._pages is not None:
                self._page_keys.append(self._pages.put(blob))
            else:
                self._store.put(
                    self._page_file(p), blob, mode=WriteMode.ASYNC_WRITEBACK
                )
            self.stats.pages_persisted += 1
            self.stats.bytes_persisted += len(blob)
        self._persisted_pages = full
        if new and self._pages is not None:
            self._write_manifest()

    def _alloc_tiers(self) -> None:
        """(Re)allocate every tier empty — the resume path after a full
        eviction freed them."""
        host_dt = np.dtype(jnp.dtype(self.dtype))
        self.cold_k = np.zeros((self.batch, self.kv, self.max_len, self.dim), host_dt)
        self.cold_v = np.zeros_like(self.cold_k)
        self.hot_k = jnp.zeros((self.batch, self.kv, self.window, self.dim), self.dtype)
        self.hot_v = jnp.zeros_like(self.hot_k)
        self._cap = self._block_k
        self._cold_k_dev = jnp.zeros((self.batch, self.kv, self._cap, self.dim), self.dtype)
        self._cold_v_dev = jnp.zeros_like(self._cold_k_dev)
        self._staged_pages = 0

    def _restore_pages(self) -> int:
        """Refill cold pages from the store; returns tokens restored."""
        per = self.batch * self.kv * self.page * self.dim * self.cold_k.dtype.itemsize
        shape = (self.batch, self.kv, self.page, self.dim)
        # Clamped at this cache's cold capacity: a store written by a
        # longer-history cache (or a name collision) must not walk the
        # restore past max_len and fail mid-copy.
        max_pages = self.max_len // self.page
        if self._pages is not None:
            keys = list(self._read_manifest().get("keys", []))[:max_pages]
            fresh = not self._page_keys  # this handle held no refs yet
            for p, key in enumerate(keys):
                blob = self._pages.fetch(key)
                lo, hi = p * self.page, (p + 1) * self.page
                self.cold_k[:, :, lo:hi, :] = np.frombuffer(
                    blob[:per], dtype=self.cold_k.dtype
                ).reshape(shape)
                self.cold_v[:, :, lo:hi, :] = np.frombuffer(
                    blob[per:], dtype=self.cold_v.dtype
                ).reshape(shape)
            self._page_keys = keys
            if fresh and keys:
                self._pages.adopt(keys)
            p = len(keys)
        else:
            p = 0
            while p < max_pages and self._store.exists(self._page_file(p)):
                blob = self._store.get(self._page_file(p))
                lo, hi = p * self.page, (p + 1) * self.page
                self.cold_k[:, :, lo:hi, :] = np.frombuffer(
                    blob[:per], dtype=self.cold_k.dtype
                ).reshape(shape)
                self.cold_v[:, :, lo:hi, :] = np.frombuffer(
                    blob[per:], dtype=self.cold_v.dtype
                ).reshape(shape)
                p += 1
        self._persisted_pages = p
        return p * self.page

    def restore_cold_from_store(self, rebuild_hot: bool = True) -> int:
        """Host-DRAM loss recovery: refill the cold history from the store.

        Restores every persisted page in order (the durable prefix — tokens
        past the last completed page were never persisted, exactly like any
        commit-on-boundary checkpoint), resets the cache's logical state
        *to that prefix* (length included: with the host tier gone, tokens
        past the boundary are unrecoverable even if a stale hot ring still
        holds them), and by default rebuilds the hot ring.  Returns the
        restored length in tokens.
        """
        if self._store is None:
            raise RuntimeError("no store attached to restore from")
        if self.cold_k is None:
            self._alloc_tiers()
        n = self._restore_pages()
        self._pending_k, self._pending_v = [], []
        self._flushed = n
        self.length = n
        self._staged_pages = 0  # staging buffer contents presumed stale
        if rebuild_hot and n:
            self.rebuild_hot_from_cold()
        return n

    # ----------------------------------------------- session evict / resume

    def evict_to_store(self) -> int:
        """Fully park the cache in the store: persist every completed page
        *and* the partial tail, then free all three tiers (hot ring, host
        history, staging buffer).  Unlike the page-boundary durability of
        the write-through path, eviction is exact — ``resume_from_store``
        restores the cache bit-identically at its full logical length, so
        an idle session costs zero HBM and zero host DRAM while parked.
        Returns the parked length in tokens."""
        if self._store is None:
            raise RuntimeError("no store attached to evict into")
        if self.cold_k is None:
            return self.length  # already parked
        from repro.core.store import WriteMode

        self.flush_host()  # drains pending + persists completed pages
        tail_lo = self._persisted_pages * self.page
        tail_n = self.length - tail_lo
        if tail_n > 0:
            blob = (
                np.ascontiguousarray(self.cold_k[:, :, tail_lo:self.length, :]).tobytes()
                + np.ascontiguousarray(self.cold_v[:, :, tail_lo:self.length, :]).tobytes()
            )
            self._store.put(self._tail_file(), blob, mode=WriteMode.ASYNC_WRITEBACK)
        self._write_manifest(tail=tail_n)
        self.stats.evictions += 1
        self.hot_k = self.hot_v = None
        self.cold_k = self.cold_v = None
        self._cold_k_dev = self._cold_v_dev = None
        self._cap = 0
        self._staged_pages = 0
        self._pending_k, self._pending_v = [], []
        return self.length

    def resume_from_store(self) -> int:
        """Un-park an evicted cache: reallocate the tiers, restore every
        page plus the tail, and rebuild the hot ring — bit-identical to
        the pre-eviction state (host tier stores the cache dtype exactly,
        so the round trip is lossless).  Returns the restored length."""
        if self._store is None:
            raise RuntimeError("no store attached to resume from")
        expect = self.length
        if self.cold_k is None:
            self._alloc_tiers()
        n = self._restore_pages()
        man = self._read_manifest()
        tail_n = int(man.get("tail", 0))
        if tail_n > 0 and self._store.exists(self._tail_file()):
            blob = self._store.get(self._tail_file())
            per = self.batch * self.kv * tail_n * self.dim * self.cold_k.dtype.itemsize
            shape = (self.batch, self.kv, tail_n, self.dim)
            self.cold_k[:, :, n : n + tail_n, :] = np.frombuffer(
                blob[:per], dtype=self.cold_k.dtype
            ).reshape(shape)
            self.cold_v[:, :, n : n + tail_n, :] = np.frombuffer(
                blob[per:], dtype=self.cold_v.dtype
            ).reshape(shape)
            n += tail_n
        self._pending_k, self._pending_v = [], []
        self._flushed = n
        self.length = n
        self._staged_pages = 0
        if n:
            self.rebuild_hot_from_cold()
        self.stats.resumes += 1
        if expect and n != expect:
            raise RuntimeError(f"resume restored {n} tokens, expected {expect}")
        return n

    def drop_staging(self) -> int:
        """Mid-decode demotion under arbiter pressure: shrink the device
        staging buffer back to one block.  Correctness is unaffected — the
        next ``attend`` re-stages needed pages from the host tier (paying
        the H2D bandwidth again); only the bandwidth amortization is
        sacrificed.  Returns the device bytes freed."""
        if self._cold_k_dev is None:
            return 0
        if self._cap == self._block_k and self._staged_pages == 0:
            return 0
        before = self.staged_device_bytes()
        self._cap = self._block_k
        self._cold_k_dev = jnp.zeros((self.batch, self.kv, self._cap, self.dim), self.dtype)
        self._cold_v_dev = jnp.zeros_like(self._cold_k_dev)
        self._staged_pages = 0
        self.stats.demotions += 1
        return before - self.staged_device_bytes()

    def close(self, delete_store_files: bool = True) -> None:
        """Retire the cache: release its arbiter pool (bytes back to the
        pot — the strand-bytes fix), drop refcounts on shared pages
        (deleting any that reach zero), delete this session's private
        store files, and free every tier.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._arb_pool is not None:
            self._arb_pool.release()
            self._arb_pool = None
        if self._pages is not None:
            for key in self._page_keys:
                self._pages.decref(key)
            self._page_keys = []
        if self._store is not None and delete_store_files:
            if self._pages is None:
                for p in range(self._persisted_pages):
                    self._store.delete(self._page_file(p))
            self._store.delete(self._tail_file())
            self._store.delete(self._manifest_file())
        self.hot_k = self.hot_v = None
        self.cold_k = self.cold_v = None
        self._cold_k_dev = self._cold_v_dev = None
        self._pending_k, self._pending_v = [], []
        self._cap = 0
        self._staged_pages = 0

    # ------------------------------------------------------------- append

    def append(self, k: jax.Array, v: jax.Array) -> None:
        """Write one token (B, KV, D): hot ring slot + queued write-through."""
        self.append_block(k[:, :, None, :], v[:, :, None, :])

    def append_block(self, k: jax.Array, v: jax.Array) -> None:
        """Write S tokens (B, KV, S, D) — prefill bulk path, one dispatch."""
        s = k.shape[2]
        if self.cold_k is None:
            raise RuntimeError("cache is evicted/closed; resume before appending")
        if self.length + s > self.max_len:
            raise ValueError("cache full")
        w = self.window
        k = k.astype(self.dtype)
        v = v.astype(self.dtype)
        if s >= w:
            order = jnp.argsort((self.length + s - w + jnp.arange(w)) % w)
            self.hot_k = jnp.take(k[:, :, -w:, :], order, axis=2)
            self.hot_v = jnp.take(v[:, :, -w:, :], order, axis=2)
        else:
            slots = (self.length + np.arange(s)) % w
            self.hot_k = self.hot_k.at[:, :, slots, :].set(k)
            self.hot_v = self.hot_v.at[:, :, slots, :].set(v)
        self._pending_k.append(k)
        self._pending_v.append(v)
        self.length += s
        self.stats.appended += s
        if self.length - self._flushed >= 2 * self.page:
            self.flush_host()

    # -------------------------------------------------------------- tiers

    @property
    def cold_len(self) -> int:
        """Tokens served from the cold tier: the page-aligned boundary
        covering everything already evicted from the hot ring."""
        evicted = self.length - self.window
        if evicted <= 0:
            return 0
        return -(-evicted // self.page) * self.page  # ceil to a page

    @property
    def hot_len(self) -> int:
        return self.length - self.cold_len

    @property
    def ring_newest(self) -> int:
        """Hot-ring slot of the most recent token."""
        return (self.length - 1) % self.window

    def device_views(self) -> tuple[jax.Array, jax.Array, int, int]:
        """(hot_k, hot_v, hot_len, ring_newest): the raw ring plus what a
        consumer needs to decode it — slot j is valid iff
        ``(ring_newest - j) mod window < hot_len``."""
        return self.hot_k, self.hot_v, self.hot_len, self.ring_newest

    def host_views(self) -> tuple[np.ndarray, np.ndarray]:
        """The written-through history [0, length) as host numpy views."""
        self.flush_host()
        n = self.length
        return self.cold_k[:, :, :n, :], self.cold_v[:, :, :n, :]

    def flush_host(self) -> None:
        """Batched write-through: one device→host transfer for all pending
        tokens (the seed path synced per token)."""
        if not self._pending_k:
            return
        ks = self._pending_k[0] if len(self._pending_k) == 1 else jnp.concatenate(self._pending_k, axis=2)
        vs = self._pending_v[0] if len(self._pending_v) == 1 else jnp.concatenate(self._pending_v, axis=2)
        self._pending_k, self._pending_v = [], []
        n = ks.shape[2]
        start = self._flushed
        assert start + n == self.length, "pending run out of sync"
        self.cold_k[:, :, start : start + n, :] = np.asarray(ks)
        self.cold_v[:, :, start : start + n, :] = np.asarray(vs)
        self._flushed = self.length
        self.stats.d2h_flushes += 1
        self.stats.bytes_written_through += 2 * ks.size * ks.dtype.itemsize
        if self._store is not None:
            self._persist_pages()

    def _ensure_capacity(self, tokens: int) -> None:
        if tokens <= self._cap:
            return
        cap = self._cap
        while cap < tokens:
            cap *= 2  # doubling: O(log T) reallocations over a decode
        cap = min(cap, -(-self.max_len // self._block_k) * self._block_k)
        grow = ((0, 0), (0, 0), (0, cap - self._cap), (0, 0))
        self._cold_k_dev = jnp.pad(self._cold_k_dev, grow)
        self._cold_v_dev = jnp.pad(self._cold_v_dev, grow)
        self._cap = cap

    def stage_cold(self) -> None:
        """Upload newly completed cold pages host→device — each exactly once
        (append-only history ⇒ completed pages are immutable).  Call ahead
        of ``attend`` to overlap the H2D copy with other dispatched work."""
        need = self.cold_len // self.page
        if need <= self._staged_pages:
            return
        self.flush_host()  # pages to stage are complete ⇒ flushable now
        self._ensure_capacity(need * self.page)
        lo, hi = self._staged_pages * self.page, need * self.page
        pk = jnp.asarray(self.cold_k[:, :, lo:hi, :])  # the H2D DMA
        pv = jnp.asarray(self.cold_v[:, :, lo:hi, :])
        self._cold_k_dev = jax.lax.dynamic_update_slice(
            self._cold_k_dev, pk, (0, 0, lo, 0)
        )
        self._cold_v_dev = jax.lax.dynamic_update_slice(
            self._cold_v_dev, pv, (0, 0, lo, 0)
        )
        self.stats.pages_staged += need - self._staged_pages
        self.stats.bytes_staged += 2 * pk.size * pk.dtype.itemsize
        self._staged_pages = need

    # ------------------------------------------------------------- attend

    def attend(self, q: jax.Array, block_k: int | None = None, impl: str = "auto") -> jax.Array:
        """Tiered decode attention for q (B, H, 1, D) over both tiers.

        The hot ring goes to the kernel as-is (no chronological gather):
        decode softmax is permutation-invariant, so ring rotation is
        position arithmetic inside the kernel (``ring_newest``).  Lengths
        are dynamic — every step reuses one compiled kernel.

        ``impl='kernel'`` runs the Pallas kernel; ``impl='xla'`` runs the
        jitted XLA oracle over the identical tiered operands.  The default
        ``'auto'`` compiles the kernel on TPU and takes the XLA path
        elsewhere — off-TPU the kernel only exists interpreted, whose
        per-step cost would measure the interpreter, not the data path.
        """
        if self.length == 0:
            raise ValueError("attend on an empty cache")
        if self.cold_k is None:
            raise RuntimeError("cache is evicted/closed; resume before attending")
        self.stage_cold()
        hot_n, cold_n = self.hot_len, self.cold_len
        self.stats.hot_hits_tokens += hot_n
        self.stats.cold_reads_tokens += cold_n
        if impl == "auto":
            impl = "kernel" if jax.default_backend() == "tpu" else "xla"
        if impl == "kernel":
            from repro.kernels import tiered_decode_attention

            if block_k is None:
                block_k = self._block_k  # sublane-aligned; divides _cap
            return tiered_decode_attention(
                q.astype(self.dtype), self.hot_k, self.hot_v,
                self._cold_k_dev, self._cold_v_dev,
                hot_len=hot_n, cold_len=cold_n, ring_newest=self.ring_newest,
                block_k=block_k,
            )
        return _xla_attend(
            q.astype(self.dtype), self.hot_k, self.hot_v,
            self._cold_k_dev, self._cold_v_dev,
            jnp.asarray(hot_n, jnp.int32), jnp.asarray(cold_n, jnp.int32),
            jnp.asarray(self.ring_newest, jnp.int32),
        )

    # ----------------------------------------------------------- recovery

    def rebuild_hot_from_cold(self) -> None:
        """Device loss: reconstruct the hot ring from the host tier — the
        paper's fault-tolerance path (re-read checkpointed blocks).  One
        vectorized gather, dtype-preserving; the staging buffer is marked
        unstaged so the next attend re-uploads the needed pages."""
        self.flush_host()
        n = min(self.length, self.window)
        pos = np.arange(self.length - n, self.length)
        ring_k = np.zeros(
            (self.batch, self.kv, self.window, self.dim), self.cold_k.dtype
        )
        ring_v = np.zeros_like(ring_k)
        ring_k[:, :, pos % self.window, :] = self.cold_k[:, :, pos, :]
        ring_v[:, :, pos % self.window, :] = self.cold_v[:, :, pos, :]
        self.hot_k = jnp.asarray(ring_k, self.dtype)
        self.hot_v = jnp.asarray(ring_v, self.dtype)
        self._staged_pages = 0  # staging buffer presumed lost with the device

    # --------------------------------------------------------- accounting

    def hot_device_bytes(self) -> int:
        if self.hot_k is None:  # evicted/closed: the ring is freed
            return 0
        return 2 * self.batch * self.kv * self.window * self.dim * jnp.dtype(self.dtype).itemsize

    def staged_device_bytes(self) -> int:
        return 2 * self.batch * self.kv * self._cap * self.dim * jnp.dtype(self.dtype).itemsize

    def device_bytes(self) -> int:
        return self.hot_device_bytes() + self.staged_device_bytes()

    def host_bytes(self) -> int:
        if self.cold_k is None:  # evicted/closed: the host tier is freed
            return 0
        return 2 * self.batch * self.kv * self.max_len * self.dim * self.cold_k.dtype.itemsize


@jax.jit
def _xla_attend(q, hot_k, hot_v, cold_k, cold_v, hot_len, cold_len, newest):
    from repro.kernels.ref import tiered_ring_attention_ref

    return tiered_ring_attention_ref(
        q, hot_k, hot_v, cold_k, cold_v, hot_len, cold_len, newest
    )
