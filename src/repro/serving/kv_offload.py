"""Two-level KV cache: a hot device window + cold host-offloaded history.

DESIGN.md §2 row L2 — the paper's architecture one level down the
hierarchy: the *device HBM* plays Tachyon (small, memory-speed, holds the
hot working set), *host DRAM* plays OrangeFS (large, slower, holds
everything).  The paper's Eq. 7 describes the blended read rate with
``f = hot_len / total_len``; its read mode (f) — nearest copy first, fall
through to the big tier — is exactly the decode path here, and the
``tiered_decode_attention`` Pallas kernel consumes the two tiers
directly (hot VMEM-resident, cold streamed).

Semantics:
* ``append(k, v)`` writes the newest token into the hot ring (device).
* When the ring wraps, the evicted token has ALREADY been written through
  to the host tier (write mode (c): every append is dual-written, so
  eviction is free — the paper's low-cost fault-tolerance argument).
* ``device_views()`` returns (hot_k, hot_v, hot_len) device arrays;
  ``host_views()`` returns the cold prefix (everything older than the
  ring) as numpy, staged to device on demand in ``cold_device_slices``.
* ``attend(q)`` runs the tiered decode kernel over both tiers.

The capacity story mirrors the paper: device budget = O(window), host
budget = O(total) — long contexts cost host memory, not HBM.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TieredKVStats:
    appended: int = 0
    hot_hits_tokens: int = 0
    cold_reads_tokens: int = 0

    def hot_fraction(self) -> float:
        total = self.hot_hits_tokens + self.cold_reads_tokens
        return self.hot_hits_tokens / total if total else 1.0


class TieredKVCache:
    """Per-layer two-level KV cache for one decoding batch.

    Shapes: k, v tokens are (B, KV, D). Hot ring: (B, KV, W, D) on device.
    Cold store: host numpy (B, KV, T_max, D), written through on append.
    """

    def __init__(self, batch: int, kv_heads: int, head_dim: int, window: int, max_len: int, dtype=jnp.bfloat16):
        if window <= 0 or max_len < window:
            raise ValueError("need 0 < window <= max_len")
        self.batch, self.kv, self.dim = batch, kv_heads, head_dim
        self.window, self.max_len = window, max_len
        self.dtype = dtype
        self.hot_k = jnp.zeros((batch, kv_heads, window, head_dim), dtype)
        self.hot_v = jnp.zeros((batch, kv_heads, window, head_dim), dtype)
        # host tier (the 'OrangeFS' of the pair): full history, numpy
        self.cold_k = np.zeros((batch, kv_heads, max_len, head_dim), np.float32)
        self.cold_v = np.zeros((batch, kv_heads, max_len, head_dim), np.float32)
        self.length = 0
        self.stats = TieredKVStats()

    # ------------------------------------------------------------- append

    def append(self, k: jax.Array, v: jax.Array) -> None:
        """Write one token (B, KV, D): hot ring slot + host write-through."""
        if self.length >= self.max_len:
            raise ValueError("cache full")
        slot = self.length % self.window
        self.hot_k = self.hot_k.at[:, :, slot, :].set(k.astype(self.dtype))
        self.hot_v = self.hot_v.at[:, :, slot, :].set(v.astype(self.dtype))
        # write mode (c): synchronous write-through to the big tier
        self.cold_k[:, :, self.length, :] = np.asarray(k, np.float32)
        self.cold_v[:, :, self.length, :] = np.asarray(v, np.float32)
        self.length += 1
        self.stats.appended += 1

    # -------------------------------------------------------------- views

    @property
    def hot_len(self) -> int:
        return min(self.length, self.window)

    @property
    def cold_len(self) -> int:
        return max(0, self.length - self.window)

    def device_views(self) -> tuple[jax.Array, jax.Array, int]:
        return self.hot_k, self.hot_v, self.hot_len

    def cold_device_slices(self) -> tuple[jax.Array, jax.Array]:
        """Stage the cold prefix to device (the 4 MB-buffer path of the
        paper corresponds to the H2D DMA here)."""
        n = self.cold_len
        ck = jnp.asarray(self.cold_k[:, :, :n, :], self.dtype)
        cv = jnp.asarray(self.cold_v[:, :, :n, :], self.dtype)
        return ck, cv

    # ------------------------------------------------------------- attend

    def attend(self, q: jax.Array, block_k: int = 512) -> jax.Array:
        """Tiered decode attention for q (B, H, 1, D) over both tiers.

        Ring slots map slot -> absolute position ``p ≡ slot (mod W)``; the
        kernel expects hot keys ordered newest-window with valid length, so
        we unroll the ring into chronological order first (cheap: W slots).
        """
        from repro.kernels import tiered_decode_attention

        hot_n = self.hot_len
        cold_n = self.cold_len
        self.stats.hot_hits_tokens += hot_n
        self.stats.cold_reads_tokens += cold_n

        # chronological hot window: positions [length-hot_n, length)
        start = self.length - hot_n
        order = jnp.arange(start, self.length) % self.window
        hk = jnp.take(self.hot_k, order, axis=2)
        hv = jnp.take(self.hot_v, order, axis=2)

        if cold_n == 0:
            ck = jnp.zeros((self.batch, self.kv, block_k, self.dim), self.dtype)
            cv = jnp.zeros_like(ck)
        else:
            ck, cv = self.cold_device_slices()
        return tiered_decode_attention(
            q.astype(self.dtype), hk, hv, ck, cv,
            hot_len=hot_n, cold_len=cold_n, block_k=block_k,
        )

    # ----------------------------------------------------------- recovery

    def rebuild_hot_from_cold(self) -> None:
        """Device loss: reconstruct the hot ring from the host tier —
        the paper's fault-tolerance path (re-read checkpointed blocks)."""
        n = self.hot_len
        start = self.length - n
        ring_k = np.zeros((self.batch, self.kv, self.window, self.dim), np.float32)
        ring_v = np.zeros_like(ring_k)
        for p in range(start, self.length):
            ring_k[:, :, p % self.window, :] = self.cold_k[:, :, p, :]
            ring_v[:, :, p % self.window, :] = self.cold_v[:, :, p, :]
        self.hot_k = jnp.asarray(ring_k, self.dtype)
        self.hot_v = jnp.asarray(ring_v, self.dtype)

    def device_bytes(self) -> int:
        return 2 * self.batch * self.kv * self.window * self.dim * jnp.dtype(self.dtype).itemsize

    def host_bytes(self) -> int:
        return 2 * self.batch * self.kv * self.max_len * self.dim * 4
