"""Group-by/aggregate on the shuffle engine — the engine's second workload.

Proves the external-sort shuffle generalizes beyond TeraSort: the same
spill/merge data path, but reducers consume the globally key-ordered
``(keys, records)`` batches and emit one aggregate row per group
(sum + count of each record's value field), vectorized with
``np.unique``/``np.add.reduceat`` and carrying the open group across
batch boundaries.

Record layout (fixed 32 bytes):

* bytes ``[0, 8)``   — big-endian group key.  Generated keys keep the
  top bit clear, so the engine's 63-bit key fold is injective and equal
  folded keys ⇔ equal group keys (records of one group are contiguous
  in the merged stream).
* bytes ``[8, 16)``  — big-endian uint value (< 2^32 at gen time, so
  sums of any realistic group count fit the output field).
* bytes ``[16, 32)`` — payload padding.

Aggregate row layout (24 bytes): key(8) | sum(8) | count(8), big-endian.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import numpy as np

from repro.apps.shuffle import ShuffleConfig, ShuffleEngine, ShuffleStats
from repro.core.store import ReadMode, TwoLevelStore, WriteMode

RECORD = 32
KEY = 8
VAL_OFF, VAL_LEN = 8, 8
AGG_RECORD = 24

MB = 2**20

_BE64 = 256 ** np.arange(7, -1, -1, dtype=np.uint64)


def _shard_name(i: int) -> str:
    return f"groupby/in_{i:04d}"


def _out_name(i: int) -> str:
    return f"groupby/agg_{i:04d}"


def _values_of(records: np.ndarray) -> np.ndarray:
    return records[:, VAL_OFF : VAL_OFF + VAL_LEN].astype(np.uint64) @ _BE64


@dataclasses.dataclass
class GroupByResult:
    label: str
    gen_s: float
    shuffle_s: float
    groups: int
    stats: ShuffleStats


def groupgen(
    store: TwoLevelStore,
    n_records: int,
    n_groups: int,
    n_shards: int = 4,
    write_mode: WriteMode | None = None,
    seed: int = 0,
) -> float:
    """Generate shards of (group-key, value, padding) records."""
    t0 = time.perf_counter()
    per = n_records // n_shards
    for i in range(n_shards):
        rng = np.random.default_rng(seed + i)
        gids = rng.integers(0, n_groups, size=per, dtype=np.uint64)
        keys = (gids * np.uint64(0x9E3779B97F4A7C15)) & np.uint64((1 << 63) - 1)
        vals = rng.integers(0, 1 << 32, size=per, dtype=np.uint64)
        recs = np.empty((per, RECORD), dtype=np.uint8)
        # big-endian byte split of keys and values
        for b in range(8):
            shift = np.uint64(8 * (7 - b))
            recs[:, b] = (keys >> shift).astype(np.uint8)
            recs[:, VAL_OFF + b] = (vals >> shift).astype(np.uint8)
        recs[:, VAL_OFF + VAL_LEN :] = rng.integers(
            0, 256, size=(per, RECORD - VAL_OFF - VAL_LEN), dtype=np.uint8
        )
        store.put(_shard_name(i), recs.tobytes(), mode=write_mode)
    return time.perf_counter() - t0


def _agg_rows(keys: np.ndarray, sums: np.ndarray, counts: np.ndarray) -> bytes:
    out = np.empty((len(keys), AGG_RECORD), dtype=np.uint8)
    for b in range(8):
        shift = np.uint64(8 * (7 - b))
        out[:, b] = (keys >> shift).astype(np.uint8)
        out[:, 8 + b] = (sums >> shift).astype(np.uint8)
        out[:, 16 + b] = (counts >> shift).astype(np.uint8)
    return out.tobytes()


def _sum_reducer(batches: Iterator[tuple[np.ndarray, np.ndarray]]) -> Iterator[bytes]:
    """Aggregate sorted batches into per-group (key, sum, count) rows.

    The last group of a batch may continue in the next one (the merge
    only guarantees global key order), so it is carried, not emitted,
    until a batch starts with a different key or the stream ends.
    """
    open_key: int | None = None
    open_sum = 0
    open_cnt = 0
    for keys, records in batches:
        if not len(keys):
            continue
        vals = _values_of(records)
        uniq, starts = np.unique(keys, return_index=True)
        sums = np.add.reduceat(vals, starts)
        counts = np.diff(np.append(starts, len(keys))).astype(np.uint64)
        if open_key is not None:
            if int(uniq[0]) == open_key:
                sums[0] += np.uint64(open_sum)
                counts[0] += np.uint64(open_cnt)
            else:
                yield _agg_rows(
                    np.array([open_key], dtype=np.uint64),
                    np.array([open_sum], dtype=np.uint64),
                    np.array([open_cnt], dtype=np.uint64),
                )
        open_key = int(uniq[-1])
        open_sum = int(sums[-1])
        open_cnt = int(counts[-1])
        if len(uniq) > 1:
            yield _agg_rows(uniq[:-1], sums[:-1], counts[:-1])
    if open_key is not None:
        yield _agg_rows(
            np.array([open_key], dtype=np.uint64),
            np.array([open_sum], dtype=np.uint64),
            np.array([open_cnt], dtype=np.uint64),
        )


def groupby_sum(
    store: TwoLevelStore,
    n_shards: int = 4,
    n_reducers: int = 4,
    read_mode: ReadMode | None = None,
    write_mode: WriteMode | None = None,
    workers: int = 1,
    memory_budget_bytes: int = 16 * MB,
    label: str = "tls",
) -> GroupByResult:
    """Group-by-key sum/count over all shards; one aggregate shard per reducer."""
    cfg = ShuffleConfig(
        n_reducers=n_reducers,
        record_bytes=RECORD,
        key_bytes=KEY,
        memory_budget_bytes=memory_budget_bytes,
        workers=workers,
        spill_mode=(
            write_mode
            if write_mode in (WriteMode.MEMORY_ONLY, WriteMode.PFS_BYPASS)
            else WriteMode.ASYNC_WRITEBACK
        ),
        output_mode=write_mode,
        read_mode=read_mode,
        prefix="groupby/shuffle",
    )
    engine = ShuffleEngine(store, cfg)
    t0 = time.perf_counter()
    stats = engine.run(
        [_shard_name(i) for i in range(n_shards)], _out_name, reducer=_sum_reducer
    )
    shuffle_s = time.perf_counter() - t0
    groups = stats.output_bytes // AGG_RECORD
    return GroupByResult(
        label=label, gen_s=0.0, shuffle_s=shuffle_s, groups=groups, stats=stats
    )


def read_aggregates(
    store: TwoLevelStore, n_reducers: int, read_mode: ReadMode | None = None
) -> dict[int, tuple[int, int]]:
    """Load all aggregate shards as {group_key: (sum, count)} (validation)."""
    out: dict[int, tuple[int, int]] = {}
    for r in range(n_reducers):
        if not store.exists(_out_name(r)):
            continue
        raw = store.get(_out_name(r), mode=read_mode)
        rows = np.frombuffer(raw, dtype=np.uint8).reshape(-1, AGG_RECORD)
        keys = rows[:, :8].astype(np.uint64) @ _BE64
        sums = rows[:, 8:16].astype(np.uint64) @ _BE64
        counts = rows[:, 16:24].astype(np.uint64) @ _BE64
        for k, s, c in zip(keys, sums, counts):
            if int(k) in out:
                raise ValueError(f"group {int(k)} split across reducers")
            out[int(k)] = (int(s), int(c))
    return out
