"""Out-of-core shuffle engine: spill-to-store external sort (DESIGN.md §9).

The defining I/O abstraction of MapReduce-class analytics on HPC storage
(Jha et al., "A Tale of Two Data-Intensive Paradigms") built on the
two-level store — so workloads are bounded by *store* capacity, not by
worker RAM:

* **Map/spill** — each mapper streams its input shard through
  ``get_buffered`` (sequential read, paper read mode (f)), accumulates
  records into a fixed-size sort buffer, and every time the buffer fills
  partitions the batch by sampled splitters, sorts it by ``(reducer,
  key)`` in one ``np.lexsort``, and spills each reducer's segment as a
  **per-reducer run file** through ``put_stream`` (``ASYNC_WRITEBACK``
  by default — Fig. 4 write mode beyond (c), so spill durability
  overlaps the next batch's compute).  One file per (batch, reducer)
  keeps every merge read whole-block aligned: no partial stripe-unit
  staging on the PFS tier, and each run is deletable the moment its one
  reader finishes.
* **Reduce/merge** — each reducer k-way-merges its runs with a chunked,
  vectorized merge: every run is read with *ranged* readahead
  (``get_buffered(offset, length)`` touches only covering blocks), a
  bounded chunk of records per run is resident, and batches that are
  globally safe to emit (key ≤ the minimum of the per-run chunk maxima)
  are sorted together with one ``np.argsort`` and streamed to the output
  shard through an :class:`~repro.core.store.AppendHandle` as the merge
  drains.  Peak engine memory is O(memory_budget + k·readahead) no
  matter the dataset size.
* Each run file has exactly one reader — its reducer — and is deleted
  from *both tiers* the moment that reducer's merge has drained it.

Memory-budget math: each of ``workers`` concurrent mappers gets a
``budget / workers`` sort batch (the sorted permutation is streamed out
in small gather slices, so no second batch-sized copy exists); the
merge gives each of ``workers`` concurrent reducers ``budget /
workers``, a quarter-share per run chunk pool (``k`` resident chunks +
their re-blocking buffers) with the rest headroom for the emit batch —
which is double-counted while live (concat + sorted copies) — so
tracked engine buffers stay ≤ 2× budget at full occupancy.  The engine tracks every
buffer it allocates in a ledger — ``ShuffleStats.peak_buffer_bytes`` is
the acceptance-gate quantity (``benchmarks/terasort_scaling.py`` gates
it ≤ 2× budget).

The engine is workload-agnostic: records are fixed-size byte rows whose
leading ``key_bytes`` fold into a uint64 sort key.  TeraSort is the
identity reducer (``apps/terasort.py``); group-by/aggregate rides the
same primitives (``apps/groupby.py``) by handing ``run`` a reducer that
consumes sorted ``(keys, records)`` batches and emits aggregate rows.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterator

import numpy as np

from repro.core.sched import StreamClass
from repro.core.store import ReadMode, TwoLevelStore, WriteMode

MB = 2**20

#: A reducer consumes globally key-ordered ``(keys, records)`` batches and
#: yields bytes-like chunks for the output shard.  ``None`` = identity.
Reducer = Callable[[Iterator[tuple[np.ndarray, np.ndarray]]], Iterator[bytes]]


def fold_keys(records: np.ndarray, key_bytes: int) -> np.ndarray:
    """Fold each record's leading ``key_bytes`` into a sortable uint64.

    Big-endian byte weights mod 2^63 — the repo-wide key convention
    (matches the seed TeraSort and ``teravalidate``).
    """
    w = 256 ** np.arange(key_bytes - 1, -1, -1, dtype=np.uint64)
    return records[:, :key_bytes].astype(np.uint64) @ w % (1 << 63)


@dataclasses.dataclass
class ShuffleConfig:
    n_reducers: int
    record_bytes: int
    key_bytes: int
    memory_budget_bytes: int = 32 * MB
    workers: int = 1
    spill_mode: WriteMode = WriteMode.ASYNC_WRITEBACK
    output_mode: WriteMode | None = None  # None = store default
    read_mode: ReadMode | None = None  # None = store default
    # Per-run PFS readahead while merging: None defers to the store (its
    # static default, or the adaptive controller's per-stream depth when
    # one is attached); an int pins it.
    merge_readahead_blocks: int | None = 1
    sample_records: int = 2048  # splitter sample size per input shard
    prefix: str = "shuffle"  # spill namespace inside the store
    cleanup_spills: bool = True


@dataclasses.dataclass
class ShuffleStats:
    records_in: int = 0
    records_out: int = 0
    input_bytes: int = 0
    spill_batches: int = 0  # sort-buffer fills across mappers
    spill_files: int = 0  # per-reducer run files written
    spill_bytes: int = 0
    merge_bytes: int = 0
    output_bytes: int = 0
    runs_merged_max: int = 0  # widest k over reducers
    peak_buffer_bytes: int = 0  # ledger peak: sort + merge + emit buffers
    spills_deleted: int = 0
    sample_s: float = 0.0
    spill_s: float = 0.0
    merge_s: float = 0.0

    @property
    def moved_bytes(self) -> int:
        """Bytes that crossed the store, both directions, all phases."""
        return self.input_bytes + 2 * self.spill_bytes + self.output_bytes

    @property
    def shuffle_s(self) -> float:
        return self.sample_s + self.spill_s + self.merge_s

    def aggregate_mbps(self) -> float:
        return self.moved_bytes / MB / self.shuffle_s if self.shuffle_s > 0 else 0.0


class _BufferLedger:
    """Tracks engine-allocated buffer bytes; records the high-water mark."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.current = 0
        self.peak = 0

    def acquire(self, n: int) -> None:
        with self._lock:
            self.current += n
            if self.current > self.peak:
                self.peak = self.current

    def release(self, n: int) -> None:
        with self._lock:
            self.current -= n


class _RunReader:
    """One sorted run: a bounded record chunk fed by a ranged stream."""

    __slots__ = ("keys", "records", "pos", "_chunks", "_engine", "_nbytes")

    def __init__(self, engine: "ShuffleEngine", name: str, offset: int, length: int,
                 chunk_records: int) -> None:
        self._engine = engine
        self._nbytes = 0
        self.keys = np.empty(0, dtype=np.uint64)
        self.records = np.empty((0, engine.cfg.record_bytes), dtype=np.uint8)
        self.pos = 0
        self._chunks = engine._record_chunks(name, offset, length, chunk_records)
        self.refill()

    @property
    def exhausted(self) -> bool:
        return self.pos >= len(self.keys) and self._chunks is None

    def refill(self) -> None:
        """Load the next chunk once the current one is fully consumed."""
        if self.pos < len(self.keys) or self._chunks is None:
            return
        # Release the drained chunk *before* decoding the next one, so the
        # ledger never counts two chunks for one run.
        self._engine._ledger.release(self._nbytes)
        self._nbytes = 0
        nxt = next(self._chunks, None)
        if nxt is None:
            self._chunks = None
            self._nbytes = 0
            self.keys = np.empty(0, dtype=np.uint64)
            self.records = np.empty((0, self.records.shape[1]), dtype=np.uint8)
        else:
            self.keys, self.records = nxt
            self._nbytes = self.records.nbytes + self.keys.nbytes
            self._engine._ledger.acquire(self._nbytes)
        self.pos = 0

    def last_key(self) -> int:
        return int(self.keys[-1])

    def take_upto(self, bound: int) -> tuple[np.ndarray, np.ndarray] | None:
        """Consume the prefix with key ≤ bound (globally safe to emit)."""
        hi = int(np.searchsorted(self.keys, bound, side="right"))
        if hi <= self.pos:
            return None
        lo, self.pos = self.pos, hi
        return self.keys[lo:hi], self.records[lo:hi]

    def close(self) -> None:
        if self._chunks is not None:
            self._chunks.close()
            self._chunks = None
        self._engine._ledger.release(self._nbytes)
        self._nbytes = 0


class ShuffleEngine:
    """Bounded-memory external-sort shuffle over a :class:`TwoLevelStore`."""

    def __init__(self, store: TwoLevelStore, cfg: ShuffleConfig) -> None:
        if cfg.n_reducers < 1 or cfg.record_bytes < 1:
            raise ValueError("n_reducers and record_bytes must be positive")
        if not 0 < cfg.key_bytes <= cfg.record_bytes:
            raise ValueError("key_bytes must be in (0, record_bytes]")
        self.store = store
        self.cfg = cfg
        self.stats = ShuffleStats()
        self._ledger = _BufferLedger()
        self._arb_pool = None  # optional arbiter lease (attach_arbiter)
        self._lock = threading.Lock()
        # reducer -> [(run file name, byte length)] — each a key-sorted run
        self._runs: dict[int, list[tuple[str, int]]] = {r: [] for r in range(cfg.n_reducers)}
        # Stream intent for the adaptive controller: spill runs are written
        # once and read exactly once by their reducer — ghost-gated
        # admission + deep sequential readahead, and flushed spill blocks
        # may be dropped from the memory tier under contention.
        store.hint_stream(cfg.prefix + "/spill/", StreamClass.SEQ_ONCE)

    def attach_arbiter(self, arbiter, *, min_bytes: int = 0, weight: float = 1.0):
        """Lease the sort-buffer budget from a :class:`MemoryArbiter`.

        The pool's grant only ever *shrinks* the live budget below
        ``cfg.memory_budget_bytes`` (never raises it), so the ledger's
        ≤ 2×-budget acceptance gate keeps its original meaning.
        """
        floor = max(int(min_bytes), self.cfg.record_bytes * max(1, self.cfg.workers))
        pool = arbiter.register(
            "shuffle_sort",
            cls="seq_once",
            min_bytes=floor,
            initial_bytes=self.cfg.memory_budget_bytes,
        )

        def value_fn() -> float:
            pool.note_used(self._ledger.current)
            # Always demand the configured budget: jobs are bursty, and a
            # demand collapse between jobs would strand the next job on the
            # floor grant until a plan tick.  SEQ_ONCE's low class base is
            # what lets other pools outbid an idle engine.
            pool.note_demand(self.cfg.memory_budget_bytes)
            return 1.0 * weight * (1.0 + 4.0 * pool.miss_rate())

        pool.value_fn = value_fn
        self._arb_pool = pool
        return pool

    def _live_budget_bytes(self) -> int:
        if self._arb_pool is not None:
            return max(self.cfg.record_bytes,
                       min(self._arb_pool.budget, self.cfg.memory_budget_bytes))
        return self.cfg.memory_budget_bytes

    # ------------------------------------------------------------- phases

    def run(self, inputs: list[str], out_name: Callable[[int], str],
            reducer: Reducer | None = None) -> ShuffleStats:
        """Shuffle ``inputs`` into ``n_reducers`` output shards.

        ``out_name(r)`` names reducer ``r``'s output file; ``reducer``
        optionally transforms each reducer's sorted stream (group-by).
        """
        cfg = self.cfg
        for name in inputs:
            # Mapper input shards are one sequential scan each — they must
            # not evict anyone's re-read working set on the way through.
            # Cleared in the finally below: the scan is over when the run
            # ends, and per-file hints must not accumulate across jobs on a
            # long-lived store (classify() walks the hint table).
            self.store.hint_stream(name, StreamClass.SEQ_ONCE)
        try:
            return self._run_impl(inputs, out_name, reducer)
        finally:
            for name in inputs:
                self.store.hint_stream(name, None)

    def _run_impl(self, inputs: list[str], out_name: Callable[[int], str],
                  reducer: Reducer | None) -> ShuffleStats:
        splitters = self.sample(inputs)
        self.map_phase(inputs, splitters)
        self.reduce_phase(out_name, reducer)
        return self.stats

    def sample(self, inputs: list[str]) -> np.ndarray:
        """Phase 1: sample input keys → the global splitter vector.

        In a distributed run, one host samples and every host maps with
        the *same* splitters (they define the reducer partitioning, so
        they must be global) — publish them however the job coordinates,
        e.g. a small store file.
        """
        t0 = time.perf_counter()
        splitters = self._sample_splitters(inputs)
        self.stats.sample_s += time.perf_counter() - t0
        return splitters

    def map_phase(self, inputs: list[str], splitters: np.ndarray,
                  mapper_base: int = 0) -> None:
        """Phase 2: map/spill ``inputs`` into per-reducer run files.

        ``mapper_base`` offsets the mapper index baked into run-file names
        — in a multi-host job each host maps its own input subset with a
        disjoint index range (host ``h`` of ``H`` passes ``h * len(all) //
        H`` or any non-overlapping base) so spill names never collide in
        the shared namespace.
        """
        cfg = self.cfg
        t0 = time.perf_counter()
        workers = max(1, cfg.workers)
        if workers > 1 and len(inputs) > 1:
            with ThreadPoolExecutor(max_workers=workers, thread_name_prefix="shuffle-map") as ex:
                list(
                    ex.map(
                        lambda mi: self._map_one(mapper_base + mi[0], mi[1], splitters),
                        enumerate(inputs),
                    )
                )
        else:
            for m, name in enumerate(inputs):
                self._map_one(mapper_base + m, name, splitters)
        self.stats.spill_s += time.perf_counter() - t0

    def reduce_phase(self, out_name: Callable[[int], str],
                     reducer: Reducer | None = None,
                     reducers: list[int] | None = None) -> None:
        """Phase 3: k-way-merge run files into output shards.

        ``reducers`` restricts this engine to a subset of reducer indexes
        — the multi-host path: :func:`place_reducers` assigns each reducer
        to the host whose memory shard holds the most of its run bytes
        hot, and each host calls ``reduce_phase(..., reducers=mine)``
        after :meth:`discover_runs`.
        """
        cfg = self.cfg
        todo = sorted(set(range(cfg.n_reducers) if reducers is None else reducers))
        for r in todo:
            if not 0 <= r < cfg.n_reducers:
                raise ValueError(f"reducer index {r} outside 0..{cfg.n_reducers - 1}")
        t0 = time.perf_counter()
        workers = max(1, cfg.workers)
        if workers > 1 and len(todo) > 1:
            with ThreadPoolExecutor(max_workers=workers, thread_name_prefix="shuffle-red") as ex:
                list(ex.map(lambda r: self._reduce_one(r, out_name(r), reducer), todo))
        else:
            for r in todo:
                self._reduce_one(r, out_name(r), reducer)
        self.stats.merge_s += time.perf_counter() - t0
        self.stats.peak_buffer_bytes = self._ledger.peak

    def discover_runs(self) -> int:
        """Rebuild the run registry from the store's file listing.

        The registry (`reducer → [(run name, length)]`) is engine-local
        state; an engine that did not run the map phase — a reducer host
        in a distributed job, or a restarted process resuming after the
        spills were written — recovers it from the shared namespace by
        the run-name pattern ``{prefix}/spill/m*-*-r{r:03d}``.  Returns
        the number of run files found.
        """
        cfg = self.cfg
        spill_prefix = f"{cfg.prefix}/spill/m"
        found = 0
        with self._lock:
            self._runs = {r: [] for r in range(cfg.n_reducers)}
            for name in self.store.list_files():
                if not name.startswith(spill_prefix):
                    continue
                tail = name.rsplit("-r", 1)
                if len(tail) != 2 or not tail[1].isdigit():
                    continue
                r = int(tail[1])
                if not 0 <= r < cfg.n_reducers:
                    continue
                self._runs[r].append((name, self.store.file_size(name)))
                found += 1
        return found

    # ------------------------------------------------------------ sampling

    def _sample_splitters(self, inputs: list[str]) -> np.ndarray:
        """Sample record keys from every input; quantiles → splitters."""
        cfg = self.cfg
        rb = cfg.record_bytes
        probes_per_shard = 8
        keys: list[np.ndarray] = []
        for name in inputs:
            size = self.store.file_size(name)
            n_rec = size // rb
            if n_rec == 0:
                continue
            per_probe = max(1, cfg.sample_records // probes_per_shard)
            for j in range(probes_per_shard):
                start = (j * n_rec) // probes_per_shard
                cnt = min(per_probe, n_rec - start)
                if cnt <= 0:
                    continue
                raw = self.store.get_range(name, start * rb, cnt * rb, mode=cfg.read_mode)
                with self._lock:
                    self.stats.input_bytes += len(raw)
                recs = np.frombuffer(raw, dtype=np.uint8)[: (len(raw) // rb) * rb]
                keys.append(fold_keys(recs.reshape(-1, rb), cfg.key_bytes))
        if not keys or cfg.n_reducers == 1:
            return np.empty(0, dtype=np.uint64)
        sample = np.concatenate(keys)
        qs = np.linspace(0, 1, cfg.n_reducers + 1)[1:-1]
        return np.quantile(sample, qs).astype(np.uint64)

    # ---------------------------------------------------------- map/spill

    def _per_mapper_batch_records(self) -> int:
        # Each concurrent mapper gets the full per-worker share: the sort
        # permutation is *streamed* out in app-buffer-sized gather slices
        # (see _spill), so no second batch-sized copy ever exists.
        per_mapper = self._live_budget_bytes() // max(1, self.cfg.workers)
        return max(1, per_mapper // self.cfg.record_bytes)

    def _map_one(self, m: int, name: str, splitters: np.ndarray) -> None:
        cfg = self.cfg
        rb = cfg.record_bytes
        batch_records = self._per_mapper_batch_records()
        buf = np.empty((batch_records, rb), dtype=np.uint8)
        self._ledger.acquire(buf.nbytes)
        fill = 0
        n_spills = 0
        read_bytes = 0
        carry = bytearray()
        try:
            for chunk in self.store.get_buffered(name, mode=cfg.read_mode):
                read_bytes += len(chunk)
                carry += chunk
                whole = (len(carry) // rb) * rb
                if not whole:
                    continue
                recs = np.frombuffer(bytes(carry[:whole]), dtype=np.uint8).reshape(-1, rb)
                del carry[:whole]
                pos = 0
                while pos < len(recs):
                    take = min(batch_records - fill, len(recs) - pos)
                    buf[fill : fill + take] = recs[pos : pos + take]
                    fill += take
                    pos += take
                    if fill == batch_records:
                        self._spill(m, n_spills, buf[:fill], splitters)
                        n_spills += 1
                        fill = 0
            if carry:
                raise ValueError(f"{name}: size not a multiple of record_bytes={rb}")
            if fill:
                self._spill(m, n_spills, buf[:fill], splitters)
        finally:
            self._ledger.release(buf.nbytes)
        with self._lock:
            self.stats.input_bytes += read_bytes

    def _run_name(self, m: int, s: int, r: int) -> str:
        return f"{self.cfg.prefix}/spill/m{m:03d}-{s:04d}-r{r:03d}"

    def _spill(self, m: int, s: int, records: np.ndarray, splitters: np.ndarray) -> None:
        """Sort one batch by (reducer, key); spill one run file per reducer.

        Separate files keep each run's merge read whole-block aligned —
        a ranged read into the middle of a shared spill file would stage
        whole boundary stripe units on the PFS tier (read amplification
        ∝ stripe/segment); a run file is read exactly once, exactly.
        """
        cfg = self.cfg
        rb = cfg.record_bytes
        keys = fold_keys(records, cfg.key_bytes)
        if len(splitters):
            dest = np.searchsorted(splitters, keys, side="right")
            order = np.lexsort((keys, dest))
            counts = np.bincount(dest, minlength=cfg.n_reducers)
        else:
            order = np.argsort(keys, kind="stable")
            counts = np.zeros(cfg.n_reducers, dtype=np.int64)
            counts[0] = len(keys)
        bounds = np.concatenate(([0], np.cumsum(counts)))
        slice_records = max(1, self.store.app_buffer_bytes // rb)
        n_files = 0
        for r in range(cfg.n_reducers):
            lo, hi = int(bounds[r]), int(bounds[r + 1])
            if hi == lo:
                continue
            idx = order[lo:hi]
            name = self._run_name(m, s, r)

            def seg_chunks(idx: np.ndarray = idx):
                # Stream the sorted permutation out in small gather slices —
                # the batch buffer is the only batch-sized allocation.
                for a in range(0, len(idx), slice_records):
                    part = records[idx[a : a + slice_records]]
                    yield memoryview(part.reshape(-1).data)

            self.store.put_stream(name, seg_chunks(), mode=cfg.spill_mode)
            n_files += 1
            with self._lock:
                self._runs[r].append((name, (hi - lo) * rb))
        with self._lock:
            self.stats.spill_batches += 1
            self.stats.spill_files += n_files
            self.stats.spill_bytes += len(records) * rb
            self.stats.records_in += len(records)

    # --------------------------------------------------------- reduce/merge

    def _record_chunks(self, name: str, offset: int, length: int,
                       chunk_records: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Decode a ranged stream into (keys, records) chunks of bounded size.

        Bytes are consumed straight off the store's streaming buffers in
        ≤ chunk-size slices, so engine-resident memory per run stays
        O(chunk) regardless of the store's app-buffer granularity.
        """
        cfg = self.cfg
        rb = cfg.record_bytes
        step = chunk_records * rb
        buf = bytearray()
        stream = self.store.get_buffered(
            name,
            mode=cfg.read_mode,
            readahead=cfg.merge_readahead_blocks,
            offset=offset,
            length=length,
        )

        def decode(b: bytes) -> tuple[np.ndarray, np.ndarray]:
            recs = np.frombuffer(b, dtype=np.uint8).reshape(-1, rb)
            return fold_keys(recs, cfg.key_bytes), recs

        read = 0
        self._ledger.acquire(step)  # the re-blocking buffer below
        try:
            for mv in stream:
                read += len(mv)
                pos = 0
                while pos < len(mv):
                    take = min(len(mv) - pos, step - len(buf))
                    buf += mv[pos : pos + take]
                    pos += take
                    if len(buf) == step:
                        blob = bytes(buf)
                        buf.clear()  # before the yield: one chunk live at a time
                        yield decode(blob)
            whole = (len(buf) // rb) * rb
            if whole != len(buf):
                raise ValueError(f"{name}: run length not a multiple of record_bytes")
            if buf:
                yield decode(bytes(buf))
        finally:
            self._ledger.release(step)
            stream.close()
            with self._lock:
                self.stats.merge_bytes += read

    def _merge_chunk_records(self, k: int) -> int:
        # Each of `workers` concurrent reducers holds k run chunks (keys +
        # records ≈ chunk bytes each, plus their re-blocking buffers) and
        # the emit batch, which is double-counted while live (concat +
        # sorted copies, see _merged_batches) and can span up to the sum of
        # all chunks — so a run's share is a quarter of the per-reducer
        # budget split k ways, keeping worst-case tracked bytes ≤ 2×budget.
        per_reducer = self._live_budget_bytes() // max(1, self.cfg.workers)
        per_run = per_reducer // (4 * max(1, k))
        return max(1, per_run // self.cfg.record_bytes)

    def _merged_batches(self, readers: list[_RunReader]) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Chunked k-way merge: emit globally-safe batches in key order.

        Invariant: any record not yet resident in a run's chunk has key ≥
        that chunk's last key, so everything ≤ the minimum of the per-run
        chunk maxima can be emitted after one batched argsort.
        """
        active = [r for r in readers if len(r.keys)]
        while active:
            bound = min(r.last_key() for r in active)
            parts_k: list[np.ndarray] = []
            parts_r: list[np.ndarray] = []
            for r in active:
                taken = r.take_upto(bound)
                if taken is not None:
                    parts_k.append(taken[0])
                    parts_r.append(taken[1])
                r.refill()
            keys = parts_k[0] if len(parts_k) == 1 else np.concatenate(parts_k)
            recs = parts_r[0] if len(parts_r) == 1 else np.concatenate(parts_r)
            # Emit accounting covers everything live while the consumer runs:
            # the concatenated batch, the argsort permutation, and the
            # gathered (sorted) copies handed downstream.
            nbytes = 2 * (keys.nbytes + recs.nbytes) + 8 * len(keys)
            self._ledger.acquire(nbytes)
            try:
                order = np.argsort(keys, kind="stable")
                yield keys[order], recs[order]
            finally:
                self._ledger.release(nbytes)
            active = [r for r in readers if not r.exhausted]

    def _reduce_one(self, r: int, out: str, reducer: Reducer | None) -> None:
        # Output-stream intent is the *client's* declaration (it owns the
        # naming and knows whether downstream re-reads) — e.g. terasort
        # hints its output prefix SEQ_ONCE; the engine registers nothing
        # per-file here, so hints cannot accumulate across jobs.
        cfg = self.cfg
        with self._lock:
            runs = sorted(self._runs[r])
            self.stats.runs_merged_max = max(self.stats.runs_merged_max, len(runs))
        chunk_records = self._merge_chunk_records(len(runs))
        readers = [_RunReader(self, name, 0, ln, chunk_records) for name, ln in runs]
        written = 0
        n_out = 0
        # A fresh shuffle replaces, never extends, a previous run's output
        # (open_append would resume at a leftover file's end).
        self.store.delete(out)
        handle = self.store.open_append(out, mode=cfg.output_mode)
        try:
            batches = self._merged_batches(readers)
            if reducer is not None:
                for chunk in reducer(batches):
                    written = handle.append_chunk(chunk)
            else:
                for _, recs in batches:
                    n_out += len(recs)
                    written = handle.append_chunk(memoryview(recs.reshape(-1).data))
        finally:
            handle.close()
            for reader in readers:
                reader.close()
        with self._lock:
            self.stats.output_bytes += written
            # A custom reducer defines its own output row shape; records_out
            # counts identity-path records only.
            self.stats.records_out += n_out
        if cfg.cleanup_spills:
            # Each run file has exactly one reader — this reducer — so its
            # spills leave both tiers the moment the merge has drained them.
            for name, _ in runs:
                self.store.delete(name)
            with self._lock:
                self._runs[r] = []
                self.stats.spills_deleted += len(runs)


def place_reducers(
    n_reducers: int,
    n_hosts: int,
    hot_bytes: dict[int, dict[str, int]],
    host_ids: list[int] | None = None,
    prefix: str = "shuffle",
) -> list[int]:
    """Assign reducers to hosts where their run bytes are already hot.

    ``hot_bytes`` is the distributed store's gossip view
    (``DistributedStore.cluster_hot_bytes()``).  A reducer's affinity to a
    host is the sum of hot bytes over that host's run files matching
    ``{prefix}/spill/m*-*-r{r:03d}`` — with async-writeback spills the
    mapper host still holds its runs in its memory shard, so the reducer
    lands where most of its merge input needs no peer or PFS transfer
    (the shuffle analogue of delay scheduling).  Greedy by descending
    affinity under a ``ceil(n_reducers / n_hosts)`` balance cap; reducers
    with no hot runs fill the least-loaded hosts.  Returns ``owners[r]`` =
    host index, for ``reduce_phase(..., reducers=[r for r in ... if
    owners[r] == me])``.
    """
    if n_hosts <= 0:
        raise ValueError("n_hosts must be positive")
    ids = list(range(n_hosts)) if host_ids is None else list(host_ids)
    if len(ids) != n_hosts:
        raise ValueError(f"host_ids has {len(ids)} entries for n_hosts={n_hosts}")
    spill_prefix = f"{prefix}/spill/m"
    affinity = np.zeros((n_reducers, n_hosts), dtype=np.int64)
    for h, hid in enumerate(ids):
        for name, nbytes in hot_bytes.get(hid, {}).items():
            if not name.startswith(spill_prefix):
                continue
            tail = name.rsplit("-r", 1)
            if len(tail) != 2 or not tail[1].isdigit():
                continue
            r = int(tail[1])
            if 0 <= r < n_reducers:
                affinity[r, h] += int(nbytes)
    cap = -(-n_reducers // n_hosts)  # ceil
    edges = sorted(
        ((-int(affinity[r, h]), r, h) for r in range(n_reducers) for h in range(n_hosts)),
    )
    owners = [-1] * n_reducers
    load = [0] * n_hosts
    for neg, r, h in edges:
        if neg == 0:
            break
        if owners[r] == -1 and load[h] < cap:
            owners[r] = h
            load[h] += 1
    for r in range(n_reducers):
        if owners[r] == -1:
            h = min(range(n_hosts), key=lambda i: (load[i], i))
            owners[r] = h
            load[h] += 1
    return owners
