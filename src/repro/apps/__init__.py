"""Data-analytics applications running on the two-level storage system."""

from repro.apps.terasort import TeraSortTimings, teragen, terasort, teravalidate

__all__ = ["TeraSortTimings", "teragen", "terasort", "teravalidate"]
