"""TeraSort on the two-level storage system (paper Section 5.3).

A faithful miniature of the benchmark's I/O pattern:

* **TeraGen** — map-only job writing random fixed-size records (10-byte
  key + payload) as shard files through a chosen write mode.
* **TeraSort** — mappers read shards (read-once), partition records by
  sampled key splitters (the shuffle), reducers sort partitions and
  write output shards (write-once).
* **TeraValidate** — reads outputs and checks global key order.

Phase wall-times + store tier stats are returned so the fig7 benchmark
can compare HDFS-style (bypass-memory ~ local-disk-only), OrangeFS-style
(PFS bypass) and two-level (tiered) storage on real moved bytes.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.store import ReadMode, TwoLevelStore, WriteMode

RECORD = 100  # bytes per record (TeraSort convention)
KEY = 10  # leading key bytes


@dataclasses.dataclass
class TeraSortTimings:
    label: str
    gen_s: float
    map_s: float
    shuffle_s: float
    reduce_s: float
    validate_s: float
    records: int
    mem_hit_rate: float

    @property
    def sort_s(self) -> float:
        return self.map_s + self.shuffle_s + self.reduce_s


def _shard_name(i: int) -> str:
    return f"terasort/in_{i:04d}"


def _out_name(i: int) -> str:
    return f"terasort/out_{i:04d}"


def teragen(
    store: TwoLevelStore,
    n_records: int,
    n_shards: int = 4,
    write_mode: WriteMode | None = None,
    seed: int = 0,
) -> float:
    """Generate and store the input; returns wall seconds."""
    t0 = time.perf_counter()
    per = n_records // n_shards
    for i in range(n_shards):
        rng = np.random.default_rng(seed + i)
        data = rng.integers(0, 256, size=(per, RECORD), dtype=np.uint8)
        store.put(_shard_name(i), data.tobytes(), mode=write_mode)
    return time.perf_counter() - t0


def terasort(
    store: TwoLevelStore,
    n_shards: int = 4,
    n_reducers: int = 4,
    read_mode: ReadMode | None = None,
    write_mode: WriteMode | None = None,
    label: str = "tls",
) -> TeraSortTimings:
    # --- map phase: read-once + partition by sampled splitters ------------
    t0 = time.perf_counter()
    shards = []
    for i in range(n_shards):
        raw = b"".join(store.get_buffered(_shard_name(i), mode=read_mode))
        shards.append(np.frombuffer(raw, dtype=np.uint8).reshape(-1, RECORD))
    # sample splitters from the first shard (Hadoop samples input splits)
    sample = shards[0][:: max(1, len(shards[0]) // 1024), :KEY]
    sample_keys = sample.astype(np.uint64) @ (256 ** np.arange(KEY - 1, -1, -1, dtype=np.uint64)) % (1 << 63)
    splitters = np.quantile(sample_keys, np.linspace(0, 1, n_reducers + 1)[1:-1]).astype(np.uint64)
    map_s = time.perf_counter() - t0

    # --- shuffle: route records to reducers -------------------------------
    t0 = time.perf_counter()
    buckets: list[list[np.ndarray]] = [[] for _ in range(n_reducers)]
    for shard in shards:
        keys = shard[:, :KEY].astype(np.uint64) @ (
            256 ** np.arange(KEY - 1, -1, -1, dtype=np.uint64)
        ) % (1 << 63)
        dest = np.searchsorted(splitters, keys, side="right")
        for r in range(n_reducers):
            buckets[r].append(shard[dest == r])
    shuffle_s = time.perf_counter() - t0

    # --- reduce: sort partitions + write-once ------------------------------
    t0 = time.perf_counter()
    n_total = 0
    for r in range(n_reducers):
        part = np.concatenate(buckets[r]) if buckets[r] else np.zeros((0, RECORD), np.uint8)
        if len(part):
            keys = part[:, :KEY].astype(np.uint64) @ (
                256 ** np.arange(KEY - 1, -1, -1, dtype=np.uint64)
            ) % (1 << 63)
            part = part[np.argsort(keys, kind="stable")]
        n_total += len(part)
        store.put(_out_name(r), part.tobytes(), mode=write_mode)
    reduce_s = time.perf_counter() - t0

    # --- validate -----------------------------------------------------------
    t0 = time.perf_counter()
    ok = teravalidate(store, n_reducers)
    validate_s = time.perf_counter() - t0
    if not ok:
        raise AssertionError("terasort output is not globally ordered")

    return TeraSortTimings(
        label=label,
        gen_s=0.0,
        map_s=map_s,
        shuffle_s=shuffle_s,
        reduce_s=reduce_s,
        validate_s=validate_s,
        records=n_total,
        mem_hit_rate=store.stats.hit_rate(),
    )


def teravalidate(store: TwoLevelStore, n_reducers: int) -> bool:
    """Global order: within-partition sorted AND partition maxima ordered."""
    prev_max: np.uint64 | None = None
    weights = 256 ** np.arange(KEY - 1, -1, -1, dtype=np.uint64)
    for r in range(n_reducers):
        raw = store.get(_out_name(r))
        if not raw:
            continue
        part = np.frombuffer(raw, dtype=np.uint8).reshape(-1, RECORD)
        keys = part[:, :KEY].astype(np.uint64) @ weights % (1 << 63)
        if len(keys) > 1 and (np.diff(keys.astype(np.int64)) < 0).any():
            return False
        if prev_max is not None and len(keys) and keys[0] < prev_max:
            return False
        if len(keys):
            prev_max = keys[-1]
    return True
