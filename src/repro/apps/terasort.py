"""TeraSort on the two-level storage system (paper Section 5.3).

A faithful miniature of the benchmark's I/O pattern, now a thin client of
the out-of-core shuffle engine (``apps/shuffle.py``):

* **TeraGen** — map-only job writing random fixed-size records (10-byte
  key + payload) as shard files through a chosen write mode.
* **TeraSort** — the engine's external sort: mappers stream shards
  (read-once) and partition/sort/spill within a fixed memory budget;
  reducers k-way-merge their spill runs with ranged readahead and stream
  output shards as the merge drains.  Peak memory is bounded by the
  budget, so TeraSort runs on datasets far larger than the memory tier —
  the whole point of the benchmark.
* **TeraValidate** — streams outputs and checks global key order without
  materializing a partition.

Phase wall-times + spill/merge stats + store tier stats are returned so
the fig7 / terasort_scaling benchmarks can compare HDFS-style
(memory-only), OrangeFS-style (PFS bypass) and two-level (tiered)
storage on real moved bytes.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.apps.shuffle import ShuffleConfig, ShuffleEngine, ShuffleStats, fold_keys
from repro.core.sched import StreamClass
from repro.core.store import ReadMode, TwoLevelStore, WriteMode

RECORD = 100  # bytes per record (TeraSort convention)
KEY = 10  # leading key bytes

MB = 2**20


def _record_keys(records: np.ndarray) -> np.ndarray:
    """Fold each record's leading KEY bytes into a sortable uint64."""
    return fold_keys(records, KEY)


@dataclasses.dataclass
class TeraSortTimings:
    label: str
    gen_s: float
    map_s: float  # map/spill phase: stream + partition + sort + spill
    shuffle_s: float  # splitter sampling (the shuffle plan)
    reduce_s: float  # k-way merge + output streaming
    validate_s: float
    records: int
    mem_hit_rate: float
    # Spill/merge accounting from the engine (out-of-core path).
    spill_files: int = 0
    spill_bytes: int = 0
    merge_runs_max: int = 0
    peak_buffer_bytes: int = 0
    shuffle_mbps: float = 0.0

    @property
    def sort_s(self) -> float:
        return self.map_s + self.shuffle_s + self.reduce_s


def _shard_name(i: int) -> str:
    return f"terasort/in_{i:04d}"


def _out_name(i: int) -> str:
    return f"terasort/out_{i:04d}"


def teragen(
    store: TwoLevelStore,
    n_records: int,
    n_shards: int = 4,
    write_mode: WriteMode | None = None,
    seed: int = 0,
    workers: int = 1,
) -> float:
    """Generate and store the input; returns wall seconds."""
    t0 = time.perf_counter()
    per = n_records // n_shards

    def gen_shard(i: int) -> None:
        rng = np.random.default_rng(seed + i)
        # Generate + stream in bounded slabs so TeraGen itself stays
        # out-of-core friendly at dataset >> RAM-budget sizes.
        slab = max(1, (8 * MB) // RECORD)

        def chunks():
            left = per
            while left:
                n = min(slab, left)
                left -= n
                yield rng.integers(0, 256, size=(n, RECORD), dtype=np.uint8).tobytes()

        store.put_stream(_shard_name(i), chunks(), mode=write_mode)

    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            list(ex.map(gen_shard, range(n_shards)))
    else:
        for i in range(n_shards):
            gen_shard(i)
    return time.perf_counter() - t0


def _spill_mode_for(write_mode: WriteMode | None) -> WriteMode:
    """Spills follow the storage organization under test.

    Memory-only and PFS-bypass runs must keep their single-tier contract;
    everything else spills via ASYNC_WRITEBACK so durability overlaps the
    next batch's sort (Fig. 4 write modes, DESIGN.md §9).
    """
    if write_mode in (WriteMode.MEMORY_ONLY, WriteMode.PFS_BYPASS):
        return write_mode
    return WriteMode.ASYNC_WRITEBACK


def terasort(
    store: TwoLevelStore,
    n_shards: int = 4,
    n_reducers: int = 4,
    read_mode: ReadMode | None = None,
    write_mode: WriteMode | None = None,
    label: str = "tls",
    workers: int = 1,
    memory_budget_bytes: int = 32 * MB,
) -> TeraSortTimings:
    """External-sort TeraSort: bounded-memory spill + merge on the store."""
    cfg = ShuffleConfig(
        n_reducers=n_reducers,
        record_bytes=RECORD,
        key_bytes=KEY,
        memory_budget_bytes=memory_budget_bytes,
        workers=workers,
        spill_mode=_spill_mode_for(write_mode),
        output_mode=write_mode,
        read_mode=read_mode,
        prefix="terasort/shuffle",
    )
    engine = ShuffleEngine(store, cfg)
    # Output shards are streamed once by the merge and scanned once by
    # TeraValidate — declare the whole prefix read-once (one bounded hint;
    # a genuine later re-reader still promotes via the ghost list).
    store.hint_stream("terasort/out_", StreamClass.SEQ_ONCE)
    stats: ShuffleStats = engine.run(
        [_shard_name(i) for i in range(n_shards)], _out_name
    )

    t0 = time.perf_counter()
    ok = teravalidate(store, n_reducers, read_mode=read_mode)
    validate_s = time.perf_counter() - t0
    if not ok:
        raise AssertionError("terasort output is not globally ordered")

    return TeraSortTimings(
        label=label,
        gen_s=0.0,
        map_s=stats.spill_s,
        shuffle_s=stats.sample_s,
        reduce_s=stats.merge_s,
        validate_s=validate_s,
        records=stats.records_out,
        mem_hit_rate=store.stats.hit_rate(),
        spill_files=stats.spill_files,
        spill_bytes=stats.spill_bytes,
        merge_runs_max=stats.runs_merged_max,
        peak_buffer_bytes=stats.peak_buffer_bytes,
        shuffle_mbps=stats.aggregate_mbps(),
    )


def teravalidate(
    store: TwoLevelStore, n_reducers: int, read_mode: ReadMode | None = None
) -> bool:
    """Global order: within-partition sorted AND partitions ordered.

    Streams each output shard through ``get_buffered`` — O(chunk) memory,
    so validation works at dataset >> memory-tier sizes too.
    """
    prev_max: int | None = None
    for r in range(n_reducers):
        if not store.exists(_out_name(r)):
            continue
        carry = bytearray()
        for chunk in store.get_buffered(_out_name(r), mode=read_mode):
            carry += chunk
            whole = (len(carry) // RECORD) * RECORD
            if not whole:
                continue
            part = np.frombuffer(bytes(carry[:whole]), dtype=np.uint8).reshape(-1, RECORD)
            del carry[:whole]
            keys = _record_keys(part)
            if len(keys) > 1 and (np.diff(keys.astype(np.int64)) < 0).any():
                return False
            if prev_max is not None and len(keys) and int(keys[0]) < prev_max:
                return False
            if len(keys):
                prev_max = int(keys[-1])
        if carry:
            return False  # trailing partial record
    return True
