"""TeraSort on the two-level storage system (paper Section 5.3).

A faithful miniature of the benchmark's I/O pattern:

* **TeraGen** — map-only job writing random fixed-size records (10-byte
  key + payload) as shard files through a chosen write mode.
* **TeraSort** — mappers read shards (read-once), partition records by
  sampled key splitters (the shuffle), reducers sort partitions and
  write output shards (write-once).
* **TeraValidate** — reads outputs and checks global key order.

The I/O rides the store's parallel data path: mappers stream shards
concurrently through ``get_buffered`` (per-block readahead overlapping PFS
stripes with the partitioning compute), and reducers sort + write their
output shards concurrently, so the PFS servers see one in-flight request
each, exactly the aggregate-throughput pattern of the paper's Section 4
model.  The shuffle itself is a single argsort-split — records are routed
to all reducers in one stable sort over destination ids instead of one
full scan per reducer.

Phase wall-times + store tier stats are returned so the fig7 benchmark
can compare HDFS-style (bypass-memory ~ local-disk-only), OrangeFS-style
(PFS bypass) and two-level (tiered) storage on real moved bytes.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.store import ReadMode, TwoLevelStore, WriteMode

RECORD = 100  # bytes per record (TeraSort convention)
KEY = 10  # leading key bytes

# Big-endian byte weights folding a 10-byte key into one uint64 (mod 2^63).
_KEY_WEIGHTS = 256 ** np.arange(KEY - 1, -1, -1, dtype=np.uint64)


def _record_keys(records: np.ndarray) -> np.ndarray:
    """Fold each record's leading KEY bytes into a sortable uint64."""
    return records[:, :KEY].astype(np.uint64) @ _KEY_WEIGHTS % (1 << 63)


@dataclasses.dataclass
class TeraSortTimings:
    label: str
    gen_s: float
    map_s: float
    shuffle_s: float
    reduce_s: float
    validate_s: float
    records: int
    mem_hit_rate: float

    @property
    def sort_s(self) -> float:
        return self.map_s + self.shuffle_s + self.reduce_s


def _shard_name(i: int) -> str:
    return f"terasort/in_{i:04d}"


def _out_name(i: int) -> str:
    return f"terasort/out_{i:04d}"


def teragen(
    store: TwoLevelStore,
    n_records: int,
    n_shards: int = 4,
    write_mode: WriteMode | None = None,
    seed: int = 0,
    workers: int = 1,
) -> float:
    """Generate and store the input; returns wall seconds."""
    t0 = time.perf_counter()
    per = n_records // n_shards

    def gen_shard(i: int) -> None:
        rng = np.random.default_rng(seed + i)
        data = rng.integers(0, 256, size=(per, RECORD), dtype=np.uint8)
        store.put(_shard_name(i), data.tobytes(), mode=write_mode)

    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            list(ex.map(gen_shard, range(n_shards)))
    else:
        for i in range(n_shards):
            gen_shard(i)
    return time.perf_counter() - t0


def _read_shard(store: TwoLevelStore, i: int, read_mode: ReadMode | None) -> np.ndarray:
    """Stream one shard through the buffered reader into a records array."""
    nbytes = store.file_size(_shard_name(i))
    out = np.empty(nbytes, dtype=np.uint8)
    pos = 0
    for chunk in store.get_buffered(_shard_name(i), mode=read_mode):
        out[pos : pos + len(chunk)] = np.frombuffer(chunk, dtype=np.uint8)
        pos += len(chunk)
    return out.reshape(-1, RECORD)


def terasort(
    store: TwoLevelStore,
    n_shards: int = 4,
    n_reducers: int = 4,
    read_mode: ReadMode | None = None,
    write_mode: WriteMode | None = None,
    label: str = "tls",
    workers: int = 1,
) -> TeraSortTimings:
    # --- map phase: read-once + partition by sampled splitters ------------
    t0 = time.perf_counter()
    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            shards = list(ex.map(lambda i: _read_shard(store, i, read_mode), range(n_shards)))
    else:
        shards = [_read_shard(store, i, read_mode) for i in range(n_shards)]
    # sample splitters from the first shard (Hadoop samples input splits)
    sample = shards[0][:: max(1, len(shards[0]) // 1024)]
    sample_keys = _record_keys(sample)
    splitters = np.quantile(sample_keys, np.linspace(0, 1, n_reducers + 1)[1:-1]).astype(np.uint64)
    map_s = time.perf_counter() - t0

    # --- shuffle: route records to reducers in one argsort-split ----------
    t0 = time.perf_counter()
    records = np.concatenate(shards) if len(shards) > 1 else shards[0]
    dest = np.searchsorted(splitters, _record_keys(records), side="right")
    order = np.argsort(dest, kind="stable")
    routed = records[order]
    counts = np.bincount(dest, minlength=n_reducers)
    bounds = np.cumsum(counts)[:-1]
    partitions = np.split(routed, bounds)
    shuffle_s = time.perf_counter() - t0

    # --- reduce: sort partitions + write-once, reducers in parallel --------
    t0 = time.perf_counter()

    def reduce_one(r: int) -> int:
        part = partitions[r]
        if len(part):
            part = part[np.argsort(_record_keys(part), kind="stable")]
        store.put(_out_name(r), part.tobytes(), mode=write_mode)
        return len(part)

    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            n_total = sum(ex.map(reduce_one, range(n_reducers)))
    else:
        n_total = sum(reduce_one(r) for r in range(n_reducers))
    reduce_s = time.perf_counter() - t0

    # --- validate -----------------------------------------------------------
    t0 = time.perf_counter()
    ok = teravalidate(store, n_reducers)
    validate_s = time.perf_counter() - t0
    if not ok:
        raise AssertionError("terasort output is not globally ordered")

    return TeraSortTimings(
        label=label,
        gen_s=0.0,
        map_s=map_s,
        shuffle_s=shuffle_s,
        reduce_s=reduce_s,
        validate_s=validate_s,
        records=n_total,
        mem_hit_rate=store.stats.hit_rate(),
    )


def teravalidate(store: TwoLevelStore, n_reducers: int) -> bool:
    """Global order: within-partition sorted AND partition maxima ordered."""
    prev_max: np.uint64 | None = None
    for r in range(n_reducers):
        raw = store.get(_out_name(r))
        if not raw:
            continue
        part = np.frombuffer(raw, dtype=np.uint8).reshape(-1, RECORD)
        keys = _record_keys(part)
        if len(keys) > 1 and (np.diff(keys.astype(np.int64)) < 0).any():
            return False
        if prev_max is not None and len(keys) and keys[0] < prev_max:
            return False
        if len(keys):
            prev_max = keys[-1]
    return True
