"""Sharded, prefetching, resumable token pipeline over the TwoLevelStore.

Design (mirrors the paper's Hadoop-on-TLS data path, DESIGN.md §2):

* The corpus is materialized as shard files in the store.  Hot shards live
  in the memory tier; every shard is persisted on the PFS tier
  (write-through), so any host can lose its cache and re-read (read mode f).
* Locality scheduling: shard ``s`` is owned by host ``s % n_hosts`` — the
  analogue of Hadoop scheduling maps onto the node holding the block, so
  most reads hit the local memory tier (the paper's high ridge).
* The loader is **deterministic and resumable**: ``state()`` returns an
  exact cursor that ``restore()`` resumes from — required by the
  checkpoint/restart story (DESIGN.md §6, test_checkpoint.py).
* Two levels of overlap: shard reads stream block-by-block through the
  store's readahead iterator (``get_buffered`` keeps PFS stripe fetches in
  flight while tokens are decoded), and a background prefetch thread keeps
  ``prefetch_depth`` whole batches staged ahead of the training step
  (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

from repro.core.store import ReadMode, TwoLevelStore, WriteMode


class SyntheticCorpus:
    """Deterministic synthetic token corpus, materialized into a store.

    Shard ``i`` is an ``int32`` token array generated from ``seed + i`` —
    reproducible across runs/hosts without shipping a dataset.
    """

    def __init__(
        self,
        store: TwoLevelStore,
        vocab_size: int,
        n_shards: int = 8,
        tokens_per_shard: int = 1 << 16,
        seed: int = 0,
        prefix: str = "corpus/shard",
    ) -> None:
        self.store = store
        self.vocab_size = vocab_size
        self.n_shards = n_shards
        self.tokens_per_shard = tokens_per_shard
        self.seed = seed
        self.prefix = prefix

    def shard_name(self, i: int) -> str:
        return f"{self.prefix}_{i:05d}"

    def generate(self, write_mode: WriteMode | None = None) -> None:
        """Materialize every shard into the store (idempotent)."""
        for i in range(self.n_shards):
            name = self.shard_name(i)
            if self.store.exists(name):
                continue
            rng = np.random.default_rng(self.seed + i)
            toks = rng.integers(0, self.vocab_size, size=self.tokens_per_shard, dtype=np.int32)
            self.store.put(name, toks.tobytes(), mode=write_mode)

    def read_shard(self, i: int, mode: ReadMode | None = None) -> np.ndarray:
        """Stream a shard into a token array without materializing the file.

        Fills a preallocated array from the store's readahead iterator, so
        PFS stripe transfers for later blocks overlap the copy-out of
        earlier ones and peak extra memory is one block, not the shard.
        """
        name = self.shard_name(i)
        nbytes = self.store.file_size(name)
        out = np.empty(nbytes // 4, dtype=np.int32)
        raw = out.view(np.uint8)
        pos = 0
        for chunk in self.store.get_buffered(name, mode=mode):
            raw[pos : pos + len(chunk)] = np.frombuffer(chunk, dtype=np.uint8)
            pos += len(chunk)
        return out


@dataclasses.dataclass
class PipelineState:
    """Exact cursor for deterministic resume."""

    epoch: int = 0
    step: int = 0  # batches already emitted

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(**d)


class ShardedLoader:
    """Yields ``(inputs, labels)`` batches for one host of a data-parallel job.

    The *global* batch is ``global_batch`` sequences; this host materializes
    rows ``[host_id::n_hosts]`` of it (``global_batch % n_hosts == 0``).
    Token stream order is a pure function of (seed, epoch, step), so any
    host — or a restarted replacement host — reconstructs its slice exactly.
    """

    def __init__(
        self,
        corpus: SyntheticCorpus,
        global_batch: int,
        seq_len: int,
        host_id: int = 0,
        n_hosts: int = 1,
        prefetch_depth: int = 2,
        state: PipelineState | None = None,
    ) -> None:
        if global_batch % n_hosts:
            raise ValueError(f"global_batch={global_batch} not divisible by n_hosts={n_hosts}")
        self.corpus = corpus
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._state = state or PipelineState()
        self.prefetch_depth = prefetch_depth
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch_depth))
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()

        total_tokens = corpus.n_shards * corpus.tokens_per_shard
        self.tokens_per_global_batch = global_batch * (seq_len + 1)
        self.steps_per_epoch = total_tokens // self.tokens_per_global_batch
        if self.steps_per_epoch == 0:
            raise ValueError(
                f"corpus too small: {total_tokens} tokens < one global batch "
                f"({self.tokens_per_global_batch})"
            )

    # ------------------------------------------------------------- sampling

    def _batch_at(self, epoch: int, step: int) -> tuple[np.ndarray, np.ndarray]:
        """Deterministic batch materialization for this host's slice."""
        span = self.seq_len + 1
        total_tokens = self.corpus.n_shards * self.corpus.tokens_per_shard
        # Epoch-level deterministic permutation of sequence windows.
        n_windows = total_tokens // span
        rng = np.random.default_rng((self.corpus.seed << 16) ^ epoch)
        perm = rng.permutation(n_windows)
        rows = []
        for b in range(self.local_batch):
            gidx = step * self.global_batch + self.host_id * self.local_batch + b
            w = int(perm[gidx % n_windows])
            start = w * span
            rows.append(self._read_span(start, span))
        arr = np.stack(rows)
        return arr[:, :-1], arr[:, 1:]

    def _read_span(self, start: int, length: int) -> np.ndarray:
        """Read [start, start+length) tokens across shard boundaries."""
        tps = self.corpus.tokens_per_shard
        out = np.empty(length, dtype=np.int32)
        filled = 0
        while filled < length:
            shard, off = divmod(start + filled, tps)
            take = min(length - filled, tps - off)
            toks = self.corpus.read_shard(shard % self.corpus.n_shards)
            out[filled : filled + take] = toks[off : off + take]
            filled += take
        return out

    # ------------------------------------------------------------- iterator

    def __iter__(self):
        return self

    def __next__(self) -> tuple[np.ndarray, np.ndarray]:
        if self._worker is None and self.prefetch_depth > 0:
            self._start_worker()
        if self.prefetch_depth > 0:
            item = self._q.get()
            if isinstance(item, Exception):
                raise item
            return item
        return self._produce()

    def _produce(self) -> tuple[np.ndarray, np.ndarray]:
        st = self._state
        batch = self._batch_at(st.epoch, st.step)
        st.step += 1
        if st.step >= self.steps_per_epoch:
            st.epoch += 1
            st.step = 0
        return batch

    def _start_worker(self) -> None:
        def run() -> None:
            while not self._stop.is_set():
                try:
                    item = self._produce()
                except Exception as exc:  # propagate into consumer
                    self._q.put(exc)
                    return
                self._q.put(item)

        self._worker = threading.Thread(target=run, daemon=True, name="loader-prefetch")
        self._worker.start()

    def close(self) -> None:
        self._stop.set()
        if self._worker is not None:
            while self._worker.is_alive():
                try:
                    self._q.get(timeout=0.05)
                except queue.Empty:
                    pass
                self._worker.join(timeout=0.05)
            self._worker = None

    # ----------------------------------------------------------- resumption

    def state(self) -> PipelineState:
        """Cursor of the *next* batch to be produced.

        Note: with prefetching, batches already queued are counted as
        consumed only once handed to the caller — callers must snapshot
        state at a step boundary (the train loop does so after draining
        the queue via ``sync()``).
        """
        return PipelineState(**dataclasses.asdict(self._state))

    def sync(self) -> PipelineState:
        """Stop prefetch, drop staged batches, return the exact cursor.

        Used right before checkpointing: the returned state resumes from
        the first batch the training loop has *not* received. Staged but
        unconsumed batches are rewound.
        """
        if self._worker is not None:
            self._stop.set()
            rewound = 0
            # Drain until the worker is dead: it may be blocked on a full
            # queue mid-put; every drained item is a produced-but-unconsumed
            # batch that must be rewound.
            while self._worker.is_alive():
                try:
                    item = self._q.get(timeout=0.05)
                    if not isinstance(item, Exception):
                        rewound += 1
                except queue.Empty:
                    pass
                self._worker.join(timeout=0.05)
            try:
                while True:
                    item = self._q.get_nowait()
                    if not isinstance(item, Exception):
                        rewound += 1
            except queue.Empty:
                pass
            self._worker = None
            self._stop = threading.Event()
            for _ in range(rewound):
                self._rewind_one()
        return self.state()

    def _rewind_one(self) -> None:
        st = self._state
        if st.step == 0:
            st.epoch -= 1
            st.step = self.steps_per_epoch - 1
        else:
            st.step -= 1

    def restore(self, state: PipelineState) -> None:
        self.sync()
        self._state = PipelineState(**dataclasses.asdict(state))
