"""Sharded, prefetching, resumable token pipeline over the TwoLevelStore.

Design (mirrors the paper's Hadoop-on-TLS data path, DESIGN.md §2):

* The corpus is materialized as shard files in the store.  Hot shards live
  in the memory tier; every shard is persisted on the PFS tier
  (write-through), so any host can lose its cache and re-read (read mode f).
* **Ranged reads, not shard re-reads:** a sequence window is fetched with
  ``store.get_range`` through a small LRU *slab cache* (fixed-size token
  slabs per shard), so one batch moves O(batch × window) bytes instead of
  the seed's O(batch × shard) full-shard re-read per window.
* **Locality scheduling (implemented):** the epoch permutation never moves
  a window out of its home shard — windows are permuted *within* each
  shard and the global order interleaves shards round-robin.  Shards are
  owned in contiguous blocks (``shard_owner``); with ``global_batch ==
  n_shards`` (the train driver's default geometry) every row of host
  ``h`` draws from a shard ``h`` owns, every step — its slab cache and
  the store's memory tier see repeat traffic (the paper's high ridge) —
  and the per-owner permutation keeps the stream a pure function of
  ``(seed, epoch)`` regardless of ``n_hosts``.  Other geometries still
  get the round-robin spread (and stable per-host residue sets whenever
  ``n_shards`` divides the global batch), just not the perfect
  row↔owned-shard match; ``LoaderStats.locality_fraction`` reports the
  achieved fraction honestly either way.
* The loader is **deterministic and resumable**: ``state()`` returns an
  exact cursor that ``restore()`` resumes from — required by the
  checkpoint/restart story (DESIGN.md §6, test_checkpoint.py).
* Two levels of overlap: shard reads stream block-by-block through the
  store's readahead iterator (``get_buffered`` keeps PFS stripe fetches in
  flight while tokens are decoded), and a background prefetch thread keeps
  ``prefetch_depth`` whole batches staged ahead of the training step
  (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from collections import OrderedDict

import numpy as np

from repro.core.sched import StreamClass
from repro.core.store import ReadMode, TwoLevelStore, WriteMode


class SyntheticCorpus:
    """Deterministic synthetic token corpus, materialized into a store.

    Shard ``i`` is an ``int32`` token array generated from ``seed + i`` —
    reproducible across runs/hosts without shipping a dataset.
    """

    def __init__(
        self,
        store: TwoLevelStore,
        vocab_size: int,
        n_shards: int = 8,
        tokens_per_shard: int = 1 << 16,
        seed: int = 0,
        prefix: str = "corpus/shard",
    ) -> None:
        self.store = store
        self.vocab_size = vocab_size
        self.n_shards = n_shards
        self.tokens_per_shard = tokens_per_shard
        self.seed = seed
        self.prefix = prefix
        # Stream intent for the adaptive controller: corpus shards are read
        # sequentially and re-read every epoch — the class whose Eq. 7
        # caching value is highest (DESIGN.md §10).
        store.hint_stream(prefix, StreamClass.SEQ_REUSE)

    def shard_name(self, i: int) -> str:
        return f"{self.prefix}_{i:05d}"

    def generate(self, write_mode: WriteMode | None = None) -> None:
        """Materialize every shard into the store (idempotent)."""
        for i in range(self.n_shards):
            name = self.shard_name(i)
            if self.store.exists(name):
                continue
            rng = np.random.default_rng(self.seed + i)
            toks = rng.integers(0, self.vocab_size, size=self.tokens_per_shard, dtype=np.int32)
            self.store.put(name, toks.tobytes(), mode=write_mode)

    def read_shard(self, i: int, mode: ReadMode | None = None) -> np.ndarray:
        """Stream a shard into a token array without materializing the file.

        Fills a preallocated array from the store's readahead iterator, so
        PFS stripe transfers for later blocks overlap the copy-out of
        earlier ones and peak extra memory is one block, not the shard.
        """
        name = self.shard_name(i)
        nbytes = self.store.file_size(name)
        out = np.empty(nbytes // 4, dtype=np.int32)
        raw = out.view(np.uint8)
        pos = 0
        for chunk in self.store.get_buffered(name, mode=mode):
            raw[pos : pos + len(chunk)] = np.frombuffer(chunk, dtype=np.uint8)
            pos += len(chunk)
        return out

    def read_tokens(self, shard: int, token_offset: int, n_tokens: int) -> np.ndarray:
        """Ranged read of ``n_tokens`` tokens from one shard — only the
        covering store blocks move (memory-tier hit or partial stripe read)."""
        raw = self.store.get_range(self.shard_name(shard), token_offset * 4, n_tokens * 4)
        return np.frombuffer(raw, dtype=np.int32)


@dataclasses.dataclass
class PipelineState:
    """Exact cursor for deterministic resume."""

    epoch: int = 0
    step: int = 0  # batches already emitted

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(**d)


@dataclasses.dataclass
class LoaderStats:
    """Two-level data-path ledger for one loader."""

    slab_hits: int = 0
    slab_misses: int = 0
    bytes_fetched: int = 0  # bytes pulled from the store (slab fills)
    local_windows: int = 0  # windows whose home shard this host owns
    remote_windows: int = 0

    def hit_rate(self) -> float:
        total = self.slab_hits + self.slab_misses
        return self.slab_hits / total if total else 0.0

    def locality_fraction(self) -> float:
        total = self.local_windows + self.remote_windows
        return self.local_windows / total if total else 0.0


class _SlabCache:
    """LRU cache of fixed-size token slabs, filled by ``store.get_range``.

    The slab is the data plane's caching unit below the store block: a
    window read touches only its covering slabs, a slab is fetched with a
    single ranged read (no full-shard materialization), and the LRU keeps
    the working set of the current permutation rounds resident.
    """

    #: token width — slabs are int32 token arrays
    TOKEN_BYTES = 4

    def __init__(self, corpus: SyntheticCorpus, slab_tokens: int, capacity: int, stats: LoaderStats) -> None:
        self.corpus = corpus
        self.slab_tokens = slab_tokens
        self.capacity = max(1, capacity)
        self.stats = stats
        self._slabs: OrderedDict[tuple[int, int], np.ndarray] = OrderedDict()

    @property
    def bytes_per_slab(self) -> int:
        return self.slab_tokens * self.TOKEN_BYTES

    def set_capacity_bytes(self, nbytes: int) -> None:
        """Retarget the cache budget (the elastic arbiter's resize hook,
        DESIGN.md §13).  Only the target moves here; a shrink drains
        through ``get``'s own LRU trim on the next fill — the cache is
        single-consumer, so no cross-thread eviction races."""
        self.capacity = max(1, int(nbytes) // self.bytes_per_slab)

    def get(self, shard: int, slab_idx: int) -> np.ndarray:
        key = (shard, slab_idx)
        slab = self._slabs.get(key)
        if slab is not None:
            self._slabs.move_to_end(key)
            self.stats.slab_hits += 1
            return slab
        off = slab_idx * self.slab_tokens
        n = min(self.slab_tokens, self.corpus.tokens_per_shard - off)
        slab = self.corpus.read_tokens(shard, off, n)
        self.stats.slab_misses += 1
        self.stats.bytes_fetched += slab.nbytes
        self._slabs[key] = slab
        while len(self._slabs) > self.capacity:
            self._slabs.popitem(last=False)
        return slab


class ShardedLoader:
    """Yields ``(inputs, labels)`` batches for one host of a data-parallel job.

    The *global* batch is ``global_batch`` sequences; this host materializes
    rows ``[host_id::n_hosts]`` of it (``global_batch % n_hosts == 0``).
    Token stream order is a pure function of (seed, epoch, step), so any
    host — or a restarted replacement host — reconstructs its slice exactly.
    """

    def __init__(
        self,
        corpus: SyntheticCorpus,
        global_batch: int,
        seq_len: int,
        host_id: int = 0,
        n_hosts: int = 1,
        prefetch_depth: int = 2,
        state: PipelineState | None = None,
        slab_tokens: int = 2048,
        cache_slabs: int = 64,
        shard_owner_map: dict[int, int] | list[int] | None = None,
    ) -> None:
        if global_batch % n_hosts:
            raise ValueError(f"global_batch={global_batch} not divisible by n_hosts={n_hosts}")
        if shard_owner_map is not None:
            owners = dict(enumerate(shard_owner_map)) if isinstance(
                shard_owner_map, (list, tuple)
            ) else dict(shard_owner_map)
            if sorted(owners) != list(range(corpus.n_shards)):
                raise ValueError(
                    f"shard_owner_map must cover shards 0..{corpus.n_shards - 1}"
                )
            bad = {s: h for s, h in owners.items() if not 0 <= h < n_hosts}
            if bad:
                raise ValueError(f"shard_owner_map assigns out-of-range hosts: {bad}")
            self.shard_owner_map: dict[int, int] | None = owners
        else:
            self.shard_owner_map = None
        self.corpus = corpus
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._state = state or PipelineState()
        self.prefetch_depth = prefetch_depth
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch_depth))
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()
        self.stats = LoaderStats()
        self.slab_tokens = max(1, min(slab_tokens, corpus.tokens_per_shard))
        self._cache = _SlabCache(corpus, self.slab_tokens, cache_slabs, self.stats)
        self._order_cache: tuple[int, np.ndarray] | None = None

        total_tokens = corpus.n_shards * corpus.tokens_per_shard
        self.tokens_per_global_batch = global_batch * (seq_len + 1)
        self.steps_per_epoch = total_tokens // self.tokens_per_global_batch
        if self.steps_per_epoch == 0:
            raise ValueError(
                f"corpus too small: {total_tokens} tokens < one global batch "
                f"({self.tokens_per_global_batch})"
            )

    # ------------------------------------------------------------- locality

    def shard_owner(self, shard: int) -> int:
        """Owner host of a shard: the explicit ``shard_owner_map`` when one
        was planned (:func:`plan_shard_placement` over the distributed
        store's gossip board, DESIGN.md §11), else contiguous blocks of
        ``n_shards/n_hosts``.

        Matches the round-robin epoch order either way: groups are walked
        owner-by-owner, so with ``global_batch == n_shards`` host ``h``'s
        rows at batch positions ``[h*local_batch, (h+1)*local_batch)`` draw
        from exactly the shards this function assigns to ``h``, every step
        — provided the placement gives each host ``n_shards/n_hosts``
        shards (which :func:`plan_shard_placement` balances to).
        (Divisibility alone is not enough: with ``global_batch > n_shards``
        a host's ``local_batch`` consecutive residues wrap around all
        shards.)
        """
        if self.shard_owner_map is not None:
            return self.shard_owner_map[shard]
        return min(shard * self.n_hosts // self.corpus.n_shards, self.n_hosts - 1)

    def attach_arbiter(self, arbiter, min_bytes: int = 0, weight: float = 1.0):
        """Register the slab cache as pool ``"loader_slabs"`` (SEQ_REUSE)
        of an elastic :class:`~repro.core.arbiter.MemoryArbiter`.

        The pool's ``value_fn`` doubles as its per-tick ledger refresh:
        slab hit/miss deltas from :class:`LoaderStats` become the miss
        rate the arbiter scales marginal value by, and a full cache
        signals demand above the current budget.  Budget changes land via
        :meth:`_SlabCache.set_capacity_bytes` (DESIGN.md §13).
        """
        cache = self._cache
        bps = cache.bytes_per_slab
        pool = arbiter.register(
            "loader_slabs",
            cls="seq_reuse",
            min_bytes=max(min_bytes, bps),
            weight=weight,
            initial_bytes=cache.capacity * bps,
            on_resize=cache.set_capacity_bytes,
        )
        last = {"h": 0, "m": 0}

        def value_fn() -> float:
            s = self.stats
            dh, dm = s.slab_hits - last["h"], s.slab_misses - last["m"]
            last.update(h=s.slab_hits, m=s.slab_misses)
            held = len(cache._slabs) * bps
            pool.note_used(held)
            # A cache running at capacity wants head-room; one with slack
            # only asks for what it holds.
            full = len(cache._slabs) >= cache.capacity
            pool.note_demand(int(cache.capacity * bps * 1.5) if full else held)
            if dh or dm:
                pool.note_hit(dh)
                pool.note_miss(dm)
            miss = dm / (dh + dm) if (dh + dm) else 0.0
            return 8.0 * weight * (1.0 + 4.0 * miss)

        pool.value_fn = value_fn
        return pool

    def _window_shard(self, w: int) -> int:
        """Home shard of window ``w`` (the shard holding its first token)."""
        return (w * (self.seq_len + 1)) // self.corpus.tokens_per_shard

    def _epoch_order(self, epoch: int) -> np.ndarray:
        """Global window order for one epoch: per-shard (hence per-owner)
        permutation, interleaved round-robin across shards.

        Pure function of ``(corpus.seed, epoch)`` and the shard→owner map
        — independent of ``host_id``, so elastic restarts and host-slice
        reassembly stay exact (every host of one job must be built with
        the same ``shard_owner_map``) while every permutation round walks
        the shards in a fixed owner-grouped cycle (consecutive global rows
        hit consecutive shards of consecutive owners; each host's rows hit
        exactly its owned shards when ``global_batch == n_shards``).  With
        the default contiguous ownership the owner-grouped cycle *is*
        shard index order, so the stream is bit-identical to what it was
        before owner maps existed.
        """
        if self._order_cache is not None and self._order_cache[0] == epoch:
            return self._order_cache[1]
        span = self.seq_len + 1
        total_tokens = self.corpus.n_shards * self.corpus.tokens_per_shard
        n_windows = total_tokens // span
        home = (np.arange(n_windows, dtype=np.int64) * span) // self.corpus.tokens_per_shard
        rng = np.random.default_rng((self.corpus.seed << 16) ^ epoch)
        # Permutations are drawn in shard index order (keeps the rng stream
        # map-independent); only the *cycle* below follows the owner map.
        perms = []
        for s in range(self.corpus.n_shards):
            g = np.flatnonzero(home == s)
            perms.append(g[rng.permutation(len(g))])
        cycle = sorted(
            range(self.corpus.n_shards), key=lambda s: (self.shard_owner(s), s)
        )
        groups = [perms[s] for s in cycle]
        order = np.empty(n_windows, dtype=np.int64)
        pos = 0
        rnd = 0
        while pos < n_windows:
            for g in groups:
                if rnd < len(g):
                    order[pos] = g[rnd]
                    pos += 1
            rnd += 1
        self._order_cache = (epoch, order)
        return order

    # ------------------------------------------------------------- sampling

    def _batch_at(self, epoch: int, step: int) -> tuple[np.ndarray, np.ndarray]:
        """Deterministic batch materialization for this host's slice."""
        span = self.seq_len + 1
        order = self._epoch_order(epoch)
        n_windows = len(order)
        rows = []
        for b in range(self.local_batch):
            gidx = step * self.global_batch + self.host_id * self.local_batch + b
            w = int(order[gidx % n_windows])
            if self.shard_owner(self._window_shard(w)) == self.host_id:
                self.stats.local_windows += 1
            else:
                self.stats.remote_windows += 1
            rows.append(self._read_span(w * span, span))
        arr = np.stack(rows)
        return arr[:, :-1], arr[:, 1:]

    def _read_span(self, start: int, length: int) -> np.ndarray:
        """Read [start, start+length) tokens across shard boundaries.

        Served slab-by-slab from the LRU cache — each miss moves one
        ranged store read of ``slab_tokens`` tokens, never a whole shard.
        """
        tps = self.corpus.tokens_per_shard
        st = self.slab_tokens
        out = np.empty(length, dtype=np.int32)
        filled = 0
        while filled < length:
            shard, off = divmod(start + filled, tps)
            slab_idx, soff = divmod(off, st)
            slab = self._cache.get(shard % self.corpus.n_shards, slab_idx)
            take = min(length - filled, len(slab) - soff)
            out[filled : filled + take] = slab[soff : soff + take]
            filled += take
        return out

    # ------------------------------------------------------------- iterator

    def __iter__(self):
        return self

    def __next__(self) -> tuple[np.ndarray, np.ndarray]:
        if self._worker is None and self.prefetch_depth > 0:
            self._start_worker()
        if self.prefetch_depth > 0:
            item = self._q.get()
            if isinstance(item, Exception):
                raise item
            return item
        return self._produce()

    def _produce(self) -> tuple[np.ndarray, np.ndarray]:
        st = self._state
        batch = self._batch_at(st.epoch, st.step)
        st.step += 1
        if st.step >= self.steps_per_epoch:
            st.epoch += 1
            st.step = 0
        return batch

    def _start_worker(self) -> None:
        def run() -> None:
            while not self._stop.is_set():
                try:
                    item = self._produce()
                except Exception as exc:  # propagate into consumer
                    self._q.put(exc)
                    return
                self._q.put(item)

        self._worker = threading.Thread(target=run, daemon=True, name="loader-prefetch")
        self._worker.start()

    def close(self) -> None:
        self._stop.set()
        if self._worker is not None:
            while self._worker.is_alive():
                try:
                    self._q.get(timeout=0.05)
                except queue.Empty:
                    pass
                self._worker.join(timeout=0.05)
            self._worker = None

    # ----------------------------------------------------------- resumption

    def state(self) -> PipelineState:
        """Cursor of the *next* batch to be produced.

        Note: with prefetching, batches already queued are counted as
        consumed only once handed to the caller — callers must snapshot
        state at a step boundary (the train loop does so after draining
        the queue via ``sync()``).
        """
        return PipelineState(**dataclasses.asdict(self._state))

    def sync(self) -> PipelineState:
        """Stop prefetch, drop staged batches, return the exact cursor.

        Used right before checkpointing: the returned state resumes from
        the first batch the training loop has *not* received. Staged but
        unconsumed batches are rewound.
        """
        if self._worker is not None:
            self._stop.set()
            rewound = 0
            # Drain until the worker is dead: it may be blocked on a full
            # queue mid-put; every drained item is a produced-but-unconsumed
            # batch that must be rewound.
            while self._worker.is_alive():
                try:
                    item = self._q.get(timeout=0.05)
                    if not isinstance(item, Exception):
                        rewound += 1
                except queue.Empty:
                    pass
                self._worker.join(timeout=0.05)
            try:
                while True:
                    item = self._q.get_nowait()
                    if not isinstance(item, Exception):
                        rewound += 1
            except queue.Empty:
                pass
            self._worker = None
            self._stop = threading.Event()
            for _ in range(rewound):
                self._rewind_one()
        return self.state()

    def _rewind_one(self) -> None:
        st = self._state
        if st.step == 0:
            # Clamp at the stream origin: rewinding past (epoch 0, step 0)
            # would fabricate an epoch −1 that never existed.
            if st.epoch <= 0:
                raise RuntimeError(
                    "pipeline cursor rewound past (epoch 0, step 0) — more "
                    "batches drained than were ever produced"
                )
            st.epoch -= 1
            st.step = self.steps_per_epoch - 1
        else:
            st.step -= 1

    def restore(self, state: PipelineState) -> None:
        self.sync()
        self._state = PipelineState(**dataclasses.asdict(state))


def plan_shard_placement(
    shard_names: list[str],
    n_hosts: int,
    hot_bytes: dict[int, dict[str, int]],
    host_ids: list[int] | None = None,
) -> list[int]:
    """Assign corpus shards to hosts where their bytes are already hot.

    ``hot_bytes`` is the distributed store's gossip view
    (``DistributedStore.cluster_hot_bytes()``: host → {file → resident
    bytes}).  Greedy by descending affinity under a balance cap of
    ``ceil(n_shards / n_hosts)`` shards per host — the cap is what lets
    :class:`ShardedLoader`'s owner-grouped epoch cycle line each host's
    batch rows up with its own shards; shards nobody holds hot fill the
    least-loaded hosts in index order.  Deterministic for a given board.

    Returns ``owners`` with ``owners[i]`` = host *index* (0..n_hosts-1) of
    ``shard_names[i]`` — pass it straight to ``ShardedLoader(...,
    shard_owner_map=owners)``.  ``host_ids`` maps index → gossip host id
    when the two differ (defaults to ``0..n_hosts-1``).
    """
    if n_hosts <= 0:
        raise ValueError("n_hosts must be positive")
    ids = list(range(n_hosts)) if host_ids is None else list(host_ids)
    if len(ids) != n_hosts:
        raise ValueError(f"host_ids has {len(ids)} entries for n_hosts={n_hosts}")
    n_shards = len(shard_names)
    cap = -(-n_shards // n_hosts)  # ceil
    # (hot bytes, shard, host index) — highest affinity first, index-order ties.
    edges = sorted(
        (
            (-int(hot_bytes.get(hid, {}).get(shard_names[s], 0)), s, h)
            for s in range(n_shards)
            for h, hid in enumerate(ids)
        ),
    )
    owners = [-1] * n_shards
    load = [0] * n_hosts
    for neg, s, h in edges:
        if neg == 0:
            break  # no hot bytes — leave for the balance fill below
        if owners[s] == -1 and load[h] < cap:
            owners[s] = h
            load[h] += 1
    for s in range(n_shards):
        if owners[s] == -1:
            h = min(range(n_hosts), key=lambda i: (load[i], i))
            owners[s] = h
            load[h] += 1
    return owners
