"""Training-data pipeline riding the two-level storage system."""

from repro.data.pipeline import (
    PipelineState,
    ShardedLoader,
    SyntheticCorpus,
    plan_shard_placement,
)

__all__ = ["PipelineState", "ShardedLoader", "SyntheticCorpus", "plan_shard_placement"]
