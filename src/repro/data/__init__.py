"""Training-data pipeline riding the two-level storage system."""

from repro.data.pipeline import PipelineState, ShardedLoader, SyntheticCorpus

__all__ = ["PipelineState", "ShardedLoader", "SyntheticCorpus"]
