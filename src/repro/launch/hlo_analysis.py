"""Trip-count-aware HLO analysis: corrected FLOPs and collective bytes.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes by ~n_layers x.  The
optimized HLO text, however, contains (a) every computation as a named
block, (b) ``while`` ops referencing their body computation and carrying
``"known_trip_count":{"n":"N"}``, and (c) op output shapes for every
line.  This module:

  1. splits the module into computations and builds a call graph
     (``body=``, ``condition=``, ``to_apply=``, ``calls=``, fusion refs),
  2. assigns each computation an execution MULTIPLIER = sum over callers
     of caller_multiplier x (trip_count if called as a while body else 1),
  3. sums dot FLOPs (2 x prod(out) x contraction) and collective payload
     bytes per computation, scaled by the multiplier.

The result is the honest per-device FLOP / collective-byte count behind
EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# `%name = f32[1,2,3]{...} op-name(%a, %b), attrs`
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_REFS = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_list(text: str) -> list[tuple[str, list[int]]]:
    return [(dt, [int(x) for x in dims.split(",") if x]) for dt, dims in _SHAPE_RE.findall(text)]


def _nbytes(dt: str, dims: list[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    while_calls: list = dataclasses.field(default_factory=list)  # (body_name, trip)
    other_calls: list = dataclasses.field(default_factory=list)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and ("{" in line or line.rstrip().endswith("->")) and "=" not in line.split("(")[0]:
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line)
    return comps


def _dot_flops(line: str, shapes: dict[str, tuple[str, list[int]]]) -> float:
    """FLOPs of a dot op: 2 * prod(output) * contraction size."""
    lhs_m = re.search(r"dot\(\s*%?([\w\.\-]+)", line)
    out_shapes = _shape_list(line.split("dot(")[0])
    if not out_shapes or lhs_m is None:
        return 0.0
    _, out_dims = out_shapes[0]
    lhs_name = lhs_m.group(1)
    contr = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if lhs_name not in shapes or contr is None:
        return 0.0
    _, lhs_dims = shapes[lhs_name]
    k = 1
    for idx in (int(x) for x in contr.group(1).split(",") if x):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * k


def analyze_computations(hlo: str) -> dict[str, CompStats]:
    comps = _split_computations(hlo)
    stats: dict[str, CompStats] = {}
    for name, lines in comps.items():
        cs = CompStats()
        shapes: dict[str, tuple[str, list[int]]] = {}
        for line in lines:
            d = _DEF_RE.match(line)
            if d:
                opname, rhs = d.groups()
                sh = _shape_list(rhs.split("(")[0])
                if sh:
                    shapes[opname] = sh[0]
            if " dot(" in line:
                cs.dot_flops += _dot_flops(line, shapes)
            if " while(" in line:
                body = re.search(r"body=%?([\w\.\-]+)", line)
                trip = _TRIP_RE.search(line)
                if body:
                    cs.while_calls.append((body.group(1), int(trip.group(1)) if trip else 1))
            else:
                for ref in _CALL_REFS.findall(line):
                    cs.other_calls.append(ref)
                bm = _BRANCHES.search(line)
                if bm:
                    for ref in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        cs.other_calls.append(ref)
            if "-done" in line:
                continue
            for coll in _COLLECTIVES:
                if f" {coll}(" in line or f" {coll}-start(" in line:
                    lhs = line.split("=", 1)
                    if len(lhs) != 2:
                        continue
                    rhs = lhs[1].strip()
                    head = rhs[: rhs.index(")") + 1] if rhs.startswith("(") else rhs.split("(")[0]
                    total = sum(_nbytes(dt, dims) for dt, dims in _shape_list(head))
                    cs.coll_bytes[coll] += total
                    cs.coll_counts[coll] += 1
                    break
        stats[name] = cs
    return stats


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, flags=re.M)
    return m.group(1) if m else None


def multipliers(stats: dict[str, CompStats], entry: str) -> dict[str, float]:
    """Execution count of each computation via call-graph fixpoint.

    The computation graph is a DAG (HLO forbids recursion), so recomputing
    from the entry until stable converges in <= depth sweeps.
    """
    mult: dict[str, float] = {entry: 1.0}
    for _ in range(len(stats) + 2):
        nm: dict[str, float] = defaultdict(float)
        nm[entry] = 1.0
        for name, cs in stats.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for body, trip in cs.while_calls:
                if body in stats:
                    nm[body] += m * trip
            for ref in cs.other_calls:
                if ref in stats and ref != name:
                    nm[ref] += m
        if dict(nm) == mult:
            break
        mult = dict(nm)
    return mult


@dataclasses.dataclass
class HloAnalysis:
    corrected_dot_flops: float
    raw_dot_flops: float
    corrected_coll_bytes: dict
    corrected_coll_counts: dict
    total_coll_bytes: float


def analyze(hlo: str) -> HloAnalysis:
    stats = analyze_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None or entry not in stats:
        entry = max(stats, key=lambda n: stats[n].dot_flops) if stats else ""
    mult = multipliers(stats, entry)
    corrected = 0.0
    raw = 0.0
    coll: dict[str, float] = defaultdict(float)
    counts: dict[str, float] = defaultdict(float)
    for name, cs in stats.items():
        m = mult.get(name, 0.0)
        raw += cs.dot_flops
        corrected += cs.dot_flops * m
        for k, v in cs.coll_bytes.items():
            coll[k] += v * m
        for k, v in cs.coll_counts.items():
            counts[k] += v * m
    return HloAnalysis(
        corrected_dot_flops=corrected,
        raw_dot_flops=raw,
        corrected_coll_bytes=dict(coll),
        corrected_coll_counts=dict(counts),
        total_coll_bytes=sum(coll.values()),
    )
