"""Serving driver: batched prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 64 --tokens 32

``--kv-window W`` routes every full-attention layer's KV through the
two-level ``TieredKVCache`` (device hot ring of W tokens + paged host
cold tier, DESIGN.md §2a); ``--kv-page`` sets the cold staging page.
The tiered loop runs eagerly (host cold tier), reports the same
throughput lines plus the two-level stats: hot fraction (the paper's
Eq. 7 f), staged H2D bytes per step, and write-through flushes.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --batch 2 --prompt-len 48 --tokens 24 --kv-window 32 --kv-page 16

``--sessions N`` switches to the production serving plane (DESIGN.md
§14): N concurrent sessions under a continuous-batching
``SessionScheduler``, each owning per-layer tiered KV caches.
``--max-batch`` bounds the per-step decode batch; ``--hbm-budget-kb`` /
``--host-budget-kb`` bound the aggregate device/host KV footprint
(over-HBM demotes staging buffers, over-host evicts idle sessions fully
into the store and resumes them bit-identically); ``--shared-prefix``
gives sessions a common prompt prefix so the refcounted page registry
stores each shared cold page once.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --sessions 8 --max-batch 2 --prompt-len 48 --tokens 16 \
        --kv-window 16 --kv-page 8 --shared-prefix 32 \
        --store-root /tmp/kvstore --host-budget-kb 256
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, make_model
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    tiered_cache_stats,
    tiered_serve_loop,
)
from repro.nn.module import init_with_axes


def serve_loop(cfg, batch: int, prompt_len: int, tokens: int, seed: int = 0):
    model = make_model(cfg)
    params, _ = init_with_axes(model.init, jax.random.PRNGKey(seed), dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    caches = model.init_caches(batch, prompt_len + tokens + 1, jnp.bfloat16)
    prefill = jax.jit(make_prefill_step(model, cfg))
    step = jax.jit(make_serve_step(model, cfg))

    t0 = time.perf_counter()
    tok, caches = prefill(params, {"inputs": prompts}, caches)
    tok = tok[:, None]
    jax.block_until_ready(tok)
    prefill_s = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(tokens):
        tok, caches = step(params, tok, caches)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), prefill_s, decode_s


def tiered_serve(cfg, batch: int, prompt_len: int, tokens: int, window: int,
                 page: int | None, seed: int = 0, store=None):
    """Decode loop routed through the two-level KV cache (eager).

    ``store`` adds the durable third level: completed cold KV pages
    persist through the (possibly distributed) two-level store.
    """
    cfg = dataclasses.replace(cfg, scan_layers=False)  # host cold tier can't ride a scan carry
    if cfg.attn_logit_softcap > 0:
        raise SystemExit("--kv-window: tiered KV does not support logit-softcap archs")
    model = make_model(cfg)
    params, _ = init_with_axes(model.init, jax.random.PRNGKey(seed), dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    gen, prefill_s, decode_s, caches = tiered_serve_loop(
        model, cfg, params, prompts, tokens, window=window, page=page, store=store
    )
    return gen, prefill_s, decode_s, tiered_cache_stats(caches)


def session_serve(cfg, n_sessions: int, max_batch: int, prompt_len: int,
                  tokens: int, window: int, page: int | None, seed: int = 0,
                  store=None, shared_prefix: int = 0,
                  hbm_bytes: int | None = None, host_bytes: int | None = None):
    """Continuous batching over ``n_sessions`` tiered sessions (eager)."""
    from repro.serving import SessionScheduler

    cfg = dataclasses.replace(cfg, scan_layers=False)
    model = make_model(cfg)
    params, _ = init_with_axes(model.init, jax.random.PRNGKey(seed), dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, min(shared_prefix, prompt_len))
    sched = SessionScheduler(
        model, cfg, params, window=window, page=page, max_batch=max_batch,
        store=store, hbm_bytes=hbm_bytes, host_bytes=host_bytes,
    )
    for _ in range(n_sessions):
        tail = rng.integers(0, cfg.vocab, prompt_len - len(shared))
        sched.submit(np.concatenate([shared, tail]).astype(np.int32), tokens)
    report = sched.run()
    sched.close()
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv-window", type=int, default=0,
                    help="route full-attention KV through the tiered cache (hot ring size)")
    ap.add_argument("--kv-page", type=int, default=0,
                    help="cold-tier staging page in tokens (default min(window, 512))")
    ap.add_argument("--sessions", type=int, default=0,
                    help="continuous-batching serving plane over N sessions (needs --kv-window)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="with --sessions: per-step decode batch bound")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="with --sessions: common prompt prefix length (page dedup)")
    ap.add_argument("--hbm-budget-kb", type=int, default=0,
                    help="with --sessions: aggregate device KV budget (0 = unbounded)")
    ap.add_argument("--host-budget-kb", type=int, default=0,
                    help="with --sessions: aggregate host KV budget (0 = unbounded; "
                         "overflow evicts idle sessions into --store-root)")
    ap.add_argument("--store-root", default="",
                    help="persist cold KV pages through a two-level store at this root")
    ap.add_argument("--distributed", action="store_true",
                    help="with --store-root: join it as a DistributedStore host shard")
    ap.add_argument("--host-id", type=int, default=1,
                    help="host id for --distributed (unique per process)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    dstore = None
    store = None
    if args.store_root and args.kv_window > 0:
        if args.distributed:
            from repro.core.dstore import DistributedStore

            dstore = DistributedStore(args.host_id, args.store_root)
            store = dstore.store  # the KV pages ride this shard's write path
        else:
            from repro.core.store import TwoLevelStore

            store = TwoLevelStore(args.store_root)
    try:
        if args.sessions > 0:
            if args.kv_window <= 0:
                raise SystemExit("--sessions requires --kv-window")
            rep = session_serve(
                cfg, args.sessions, args.max_batch, args.prompt_len, args.tokens,
                window=args.kv_window, page=args.kv_page or None, store=store,
                shared_prefix=args.shared_prefix,
                hbm_bytes=args.hbm_budget_kb * 1024 or None,
                host_bytes=args.host_budget_kb * 1024 or None,
            )
            print(f"sessions {rep['sessions']} (retired {rep['retired']}) over "
                  f"{rep['steps']} steps, max_batch {args.max_batch}")
            print(f"decode {rep['decoded_tokens']} tokens: {rep['decode_s']:.3f}s "
                  f"({rep['decode_tok_per_s']:,.0f} tok/s aggregate)")
            print(f"ttft p50 {rep['ttft_p50_s']*1e3:.1f}ms  p99 {rep['ttft_p99_s']*1e3:.1f}ms")
            print(f"tier overflow: {rep['demotions']} demotions, "
                  f"{rep['evictions']} evictions, {rep['resumes']} resumes")
            if "dedup_ratio" in rep:
                print(f"shared pages: {rep['pages_logical']} logical / "
                      f"{rep['pages_stored']} stored (dedup {rep['dedup_ratio']:.2f}x)")
            return
        if args.kv_window > 0:
            gen, prefill_s, decode_s, st = tiered_serve(
                cfg, args.batch, args.prompt_len, args.tokens,
                window=args.kv_window, page=args.kv_page or None, store=store,
            )
        else:
            gen, prefill_s, decode_s = serve_loop(cfg, args.batch, args.prompt_len, args.tokens)
            st = None
    finally:
        if dstore is not None:
            dstore.close()
        elif store is not None:
            store.close()
    print(f"prefill {args.batch}x{args.prompt_len}: {prefill_s:.3f}s "
          f"({args.batch*args.prompt_len/prefill_s:,.0f} tok/s)")
    print(f"decode {args.tokens} steps: {decode_s:.3f}s "
          f"({args.batch*args.tokens/decode_s:,.0f} tok/s)")
    if st is not None and st["layers"]:
        steps = max(1, args.tokens)
        print(f"tiered KV ({st['layers']} layers, window {st['window']}, page {st['page']}): "
              f"hot fraction f={st['hot_fraction']:.3f}, "
              f"staged {st['bytes_staged']/steps:,.0f} B/step over {steps} steps "
              f"({st['pages_staged']} pages, each uploaded once), "
              f"{st['d2h_flushes']} batched write-through flushes")
    print(f"generated (row 0): {np.asarray(gen[0]).tolist()[:24]}")


if __name__ == "__main__":
    main()
