"""Serving driver: batched prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --prompt-len 64 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, make_model
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.nn.module import init_with_axes


def serve_loop(cfg, batch: int, prompt_len: int, tokens: int, seed: int = 0):
    model = make_model(cfg)
    params, _ = init_with_axes(model.init, jax.random.PRNGKey(seed), dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    caches = model.init_caches(batch, prompt_len + tokens + 1, jnp.bfloat16)
    prefill = jax.jit(make_prefill_step(model, cfg))
    step = jax.jit(make_serve_step(model, cfg))

    t0 = time.perf_counter()
    tok, caches = prefill(params, {"inputs": prompts}, caches)
    tok = tok[:, None]
    jax.block_until_ready(tok)
    prefill_s = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(tokens):
        tok, caches = step(params, tok, caches)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), prefill_s, decode_s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    gen, prefill_s, decode_s = serve_loop(cfg, args.batch, args.prompt_len, args.tokens)
    print(f"prefill {args.batch}x{args.prompt_len}: {prefill_s:.3f}s "
          f"({args.batch*args.prompt_len/prefill_s:,.0f} tok/s)")
    print(f"decode {args.tokens} steps: {decode_s:.3f}s "
          f"({args.batch*args.tokens/decode_s:,.0f} tok/s)")
    print(f"generated (row 0): {np.asarray(gen[0]).tolist()[:24]}")


if __name__ == "__main__":
    main()
