"""Mesh construction for the production pods and local runs.

Functions, not module-level constants: importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
the dry-run sees 512 host-platform placeholders).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def _mk(shape, axes) -> Mesh:
    # jax >= 0.5 takes explicit axis types; older releases have neither the
    # enum nor the kwarg — fall back to the positional form.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 dual-pod (512 chips) mesh.

    Axes: ``data`` (+ ``pod``) carry data parallelism; ``model`` carries
    tensor/expert parallelism.  The dry-run requires
    XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
    import (see ``dryrun.py``).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(n_model: int = 1) -> Mesh:
    """Mesh over whatever devices exist locally (tests/examples)."""
    n = jax.device_count()
    if n % n_model:
        raise ValueError(f"{n} devices not divisible by model={n_model}")
    return _mk((n // n_model, n_model), ("data", "model"))


def dp_axis_names(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in dp_axis_names(mesh):
        out *= mesh.shape[a]
    return out
