"""Step builders: loss, train_step, prefill/serve steps, input specs,
and sharding resolution for states/batches/caches.

Everything here is mesh-agnostic until ``*_shardings`` binds a Mesh via
the shard-if-divisible rules (``repro.nn.module``) — this is what lets a
single code path lower on 1 CPU device, a 256-chip pod, or the 512-chip
dual-pod mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import dp_axis_names, dp_size
from repro.nn.module import logical_to_pspec
from repro.optim.adamw import AdamW, apply_updates

PyTree = Any

MOE_AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3
Z_LOSS_WEIGHT = 1e-4
IGNORE_INDEX = -100


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean CE over non-ignored positions + z-loss. logits fp32 (B,S,V)."""
    mask = (labels != IGNORE_INDEX).astype(jnp.float32)
    safe = jnp.where(labels == IGNORE_INDEX, 0, labels)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    n = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / n
    zloss = Z_LOSS_WEIGHT * ((logz * mask) ** 2).sum() / n
    return loss + zloss, loss


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_loss_fn(model, cfg: ArchConfig) -> Callable:
    def loss_fn(params: PyTree, batch: dict) -> tuple[jax.Array, dict]:
        if cfg.encdec is not None:
            logits, aux = model.train_logits(params, batch["frames"], batch["inputs"])
        elif cfg.vlm is not None:
            logits, aux = model.train_logits(params, batch["inputs"], batch["patches"])
        elif cfg.mtp:
            hidden, aux = model.train_hidden(params, batch["inputs"])
            from repro.nn import layers as L  # local to avoid cycle

            x = L.norm_apply(params["final_norm"], hidden, cfg)
            logits = L.logits_apply(params["embed"], params.get("head"), x, cfg)
        else:
            logits, aux = model.train_logits(params, batch["inputs"])

        total, ce = cross_entropy(logits, batch["labels"])
        metrics = {"ce": ce}
        if cfg.moe is not None:
            total = total + MOE_AUX_WEIGHT * aux
            metrics["moe_aux"] = aux
        if cfg.mtp and cfg.encdec is None and cfg.vlm is None:
            # Predict t+2: inputs shifted by one feed the MTP head.
            mtp_logits = model.mtp_logits(params, batch["inputs"][:, 1:], hidden[:, :-1])
            mtp_total, mtp_ce = cross_entropy(mtp_logits, batch["labels"][:, 1:])
            total = total + MTP_WEIGHT * mtp_total
            metrics["mtp_ce"] = mtp_ce
        return total, metrics

    return loss_fn


def make_train_step(model, cfg: ArchConfig, optimizer: AdamW, accum_steps: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_steps > 1`` scans over microbatches, accumulating grads in
    fp32 — the standard way to hold the global batch while bounding
    activation memory (and a §Perf lever).
    """
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]), batch
            )

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / accum_steps, acc_g, grads
                )
                return (acc_g, acc_l + loss / accum_steps), metrics

            zero_g = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), mstack = jax.lax.scan(body, (zero_g, 0.0), micro)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], mstack)

        updates, opt_state, opt_metrics = optimizer.update(grads, state["opt"], params)
        new_params = apply_updates(params, updates)
        new_state = {"params": new_params, "opt": opt_state, "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    return train_step


def init_state(model, cfg: ArchConfig, optimizer: AdamW, rng: jax.Array, abstract: bool = False):
    """(state, axes) — axes only covers params; opt m/v share them."""
    from repro.nn.module import init_with_axes

    params, axes = init_with_axes(model.init, rng, abstract=abstract, dtype=jnp.dtype(cfg.param_dtype))
    if abstract:
        opt = jax.eval_shape(optimizer.init, params)
        step = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        opt = optimizer.init(params)
        step = jnp.zeros((), jnp.int32)
    return {"params": params, "opt": opt, "step": step}, axes


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(model, cfg: ArchConfig) -> Callable:
    if cfg.encdec is not None:
        def prefill_step(params, batch, caches):
            logits, caches = model.prefill(params, batch["frames"], batch["inputs"], caches)
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), caches
    elif cfg.vlm is not None:
        def prefill_step(params, batch, caches):
            logits, caches = model.prefill(params, batch["inputs"], caches, patches=batch["patches"])
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), caches
    else:
        def prefill_step(params, batch, caches):
            logits, caches = model.prefill(params, batch["inputs"], caches)
            return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), caches

    return prefill_step


def make_serve_step(model, cfg: ArchConfig) -> Callable:
    def serve_step(params, token: jax.Array, caches) -> tuple[jax.Array, PyTree]:
        logits, caches = model.decode_step(params, token, caches)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    return serve_step


# ---------------------------------------------------------------------------
# Tiered (two-level KV) serving — DESIGN.md §2a
# ---------------------------------------------------------------------------


def make_tiered_caches(
    model, cfg: ArchConfig, batch: int, max_len: int, window: int, page: int | None,
    dtype=jnp.bfloat16, store=None, store_prefix: str = "serving/kv", pages=None,
) -> dict:
    """Caches for the two-level serving backend: every full-attention GQA
    layer gets a ``TieredKVCache`` (device hot ring + paged host cold tier);
    windowed/recurrent/MLA layers keep their standard O(window)/O(1) caches.

    ``store`` (a :class:`~repro.core.store.TwoLevelStore`, e.g. one host
    shard of a :class:`~repro.core.dstore.DistributedStore`) adds the
    third level: completed cold pages persist under
    ``<store_prefix>/prefix_<i>/`` so KV history survives host DRAM loss
    (``restore_cold_from_store``).  ``pages`` (a
    :class:`~repro.serving.SharedPageRegistry`) routes completed pages
    through the content-addressed refcounted table instead, so sessions
    sharing a prompt prefix store each shared page once.

    Requires an unrolled stack (``cfg.scan_layers=False``) — the cold tier
    is host state, which cannot ride a ``lax.scan`` carry.
    """
    from repro.models.lm import make_layer_cache  # local to avoid cycle
    from repro.serving import TieredKVCache

    if model.n_periods:
        raise ValueError("tiered serving needs an unrolled stack (cfg.scan_layers=False)")
    hd = cfg.resolved_head_dim
    caches: dict[str, Any] = {}
    for i, spec in enumerate(model.prefix):
        if spec.mixer == "gqa" and spec.window == 0:
            caches[f"prefix_{i}"] = TieredKVCache(
                batch, cfg.n_kv_heads, hd, window=window, max_len=max_len,
                dtype=dtype, page=page,
                store=store, store_prefix=store_prefix, name=f"prefix_{i}",
                pages=pages,
            )
        else:
            caches[f"prefix_{i}"] = make_layer_cache(spec, cfg, batch, max_len, dtype)
    return caches


def tiered_serve_loop(
    model,
    cfg: ArchConfig,
    params: PyTree,
    prompts: jax.Array,  # (B, S) int32
    tokens: int,
    window: int,
    page: int | None = None,
    dtype=jnp.bfloat16,
    store=None,
    store_prefix: str = "serving/kv",
) -> tuple[jax.Array, float, float, dict]:
    """Batched prefill + greedy decode routed through the two-level KV
    cache.  Runs eagerly (the cold tier is host memory; pages are staged
    to device between steps).  Returns (generated, prefill_s, decode_s,
    caches) — read per-layer ``TieredKVStats`` off the caches.
    """
    import time

    batch, prompt_len = prompts.shape
    max_len = prompt_len + tokens + 1
    caches = make_tiered_caches(
        model, cfg, batch, max_len, window, page, dtype,
        store=store, store_prefix=store_prefix,
    )

    t0 = time.perf_counter()
    logits, caches = model.prefill(params, prompts, caches)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    prefill_s = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(tokens):
        logits, caches = model.decode_step(params, tok, caches)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), prefill_s, decode_s, caches


def tiered_cache_stats(caches: dict) -> dict:
    """Aggregate ``TieredKVStats`` across the tiered layers of a cache dict
    (hot fraction, staged H2D bytes, write-through flushes)."""
    from repro.serving import TieredKVCache

    tiered = [c for c in caches.values() if isinstance(c, TieredKVCache)]
    if not tiered:
        return {"layers": 0}
    return {
        "layers": len(tiered),
        "length": tiered[0].length,
        "window": tiered[0].window,
        "page": tiered[0].page,
        "hot_fraction": sum(c.stats.hot_fraction() for c in tiered) / len(tiered),
        "bytes_staged": sum(c.stats.bytes_staged for c in tiered),
        "pages_staged": sum(c.stats.pages_staged for c in tiered),
        "bytes_written_through": sum(c.stats.bytes_written_through for c in tiered),
        "d2h_flushes": sum(c.stats.d2h_flushes for c in tiered),
        "hot_device_bytes": sum(c.hot_device_bytes() for c in tiered),
        "host_bytes": sum(c.host_bytes() for c in tiered),
    }


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Train/prefill batch ShapeDtypeStructs for one shape cell."""
    b, s = cell.global_batch, cell.seq_len
    if cfg.encdec is not None:
        spec = {
            "frames": _sds((b, cfg.encdec.n_frames, cfg.encdec.frame_dim), cfg.dtype),
            "inputs": _sds((b, s), jnp.int32),
        }
    elif cfg.vlm is not None:
        text = s - cfg.vlm.n_patches
        spec = {
            "inputs": _sds((b, text), jnp.int32),
            "patches": _sds((b, cfg.vlm.n_patches, cfg.vlm.patch_dim), cfg.dtype),
        }
    else:
        spec = {"inputs": _sds((b, s), jnp.int32)}
    if cell.kind == "train":
        label_s = spec["inputs"].shape[1]
        spec["labels"] = _sds((b, label_s), jnp.int32)
    return spec


def cache_specs(model, cfg: ArchConfig, cell: ShapeCell) -> PyTree:
    """Abstract KV-cache/recurrent-state tree for a decode/prefill cell."""
    b = cell.global_batch
    max_seq = cell.seq_len
    if cfg.vlm is not None:
        max_seq = max_seq  # patches included in cell seq_len budget
    dtype = jnp.dtype(cfg.dtype)

    def build():
        return model.init_caches(b, max_seq, dtype)

    caches = jax.eval_shape(build)
    if cfg.encdec is not None:
        # decode-time cross KV comes from prefill; build its abstract shape
        hd = cfg.resolved_head_dim
        cross = {
            "k": _sds((cfg.n_layers, b, cfg.encdec.n_frames, cfg.n_kv_heads, hd), dtype),
            "v": _sds((cfg.n_layers, b, cfg.encdec.n_frames, cfg.n_kv_heads, hd), dtype),
        }
        caches = {"self": caches["self"], "cross": cross}
    return caches


def token_specs(cfg: ArchConfig, cell: ShapeCell) -> jax.ShapeDtypeStruct:
    return _sds((cell.global_batch, 1), jnp.int32)


def input_specs(model, cfg: ArchConfig, cell: ShapeCell) -> dict:
    """All abstract inputs for the cell's step function (the dry-run entry).

    train  -> {"batch": ...}
    prefill-> {"batch": ..., "caches": ...}
    decode -> {"token": ..., "caches": ...}
    """
    if cell.kind == "train":
        return {"batch": batch_specs(cfg, cell)}
    if cell.kind == "prefill":
        return {"batch": batch_specs(cfg, cell), "caches": cache_specs(model, cfg, cell)}
    return {"token": token_specs(cfg, cell), "caches": cache_specs(model, cfg, cell)}


# ---------------------------------------------------------------------------
# Sharding resolution
# ---------------------------------------------------------------------------


def _shard_if(dim: int, axes: tuple[str, ...], mesh: Mesh):
    import numpy as np

    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if size > 1 and dim % size == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def state_shardings(state_shapes: PyTree, axes: PyTree, mesh: Mesh, rules=None) -> PyTree:
    """NamedShardings for {params, opt, step} from the params axes tree."""
    pspecs = logical_to_pspec(axes, state_shapes["params"], mesh, rules)
    ns = lambda spec: NamedSharding(mesh, spec)
    params_sh = jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P))
    return {
        "params": params_sh,
        "opt": {
            "m": params_sh,
            "v": params_sh,
            "count": NamedSharding(mesh, P()),
        },
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(batch_shapes: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading batch dim over (pod, data); replicate the rest."""
    dp = dp_axis_names(mesh)

    def one(leaf):
        lead = _shard_if(leaf.shape[0], dp, mesh)
        return NamedSharding(mesh, P(lead, *([None] * (len(leaf.shape) - 1))))

    return jax.tree_util.tree_map(one, batch_shapes)


def cache_shardings(cache_shapes: PyTree, cfg: ArchConfig, mesh: Mesh, seq_shard: bool = False) -> PyTree:
    """Cache sharding: batch over DP, head-like dims over 'model' when
    divisible. ``seq_shard=True`` shards the cache sequence dim over
    'model' instead (long-context lever for kv=1 archs; §Perf)."""
    dp = dp_axis_names(mesh)

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        rank = len(shape)
        # Stacked layer dim(s) first? detect: caches under "periods"/"self"
        # have a leading layers dim added by vmap/scan stacking.
        stacked = any(
            getattr(p, "key", None) in ("periods", "self", "cross") for p in path
        )
        spec: list = [None] * rank
        if name == "index":
            return NamedSharding(mesh, P(*([None] * rank)))
        off = 1 if stacked else 0
        bdim = off  # batch position
        if rank > bdim:
            spec[bdim] = _shard_if(shape[bdim], dp, mesh)
        if name in ("k", "v"):
            # (layers?, B, S, KV, hd)
            if seq_shard and rank >= bdim + 2:
                spec[bdim + 1] = _shard_if(shape[bdim + 1], ("model",), mesh)
            elif rank >= bdim + 3:
                spec[bdim + 2] = _shard_if(shape[bdim + 2], ("model",), mesh)
        elif name in ("c_kv", "k_pe"):
            if seq_shard and rank >= bdim + 2:
                spec[bdim + 1] = _shard_if(shape[bdim + 1], ("model",), mesh)
        elif name in ("h", "conv"):  # rglru states: (..., W) width last
            spec[rank - 1] = _shard_if(shape[rank - 1], ("model",), mesh)
        elif name in ("C", "n"):  # mlstm: (..., H, dh[, dh])
            if rank >= bdim + 2:
                spec[bdim + 1] = _shard_if(shape[bdim + 1], ("model",), mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
