"""Resilient training driver: the paper's storage system under a real loop.

Wiring (DESIGN.md §2): the token pipeline reads through the TwoLevelStore
(hot shards in the memory tier, all shards durable on the PFS tier); the
checkpoint manager writes two-level checkpoints (sync or async); a
heartbeat watches liveness; a failure injector simulates host loss; on
failure the driver restores the last committed checkpoint AND the exact
pipeline cursor, then continues — the recovery path is the paper's read
mode (f): memory tier first, PFS fallback.

CLI:  python -m repro.launch.train --arch starcoder2-3b --steps 20 --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, make_model
from repro.core.store import TwoLevelStore
from repro.data.pipeline import PipelineState, ShardedLoader, SyntheticCorpus
from repro.launch.steps import init_state, make_train_step
from repro.optim.adamw import AdamW, cosine_warmup
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.failure import FailureInjector, Heartbeat, SimulatedFailure
from repro.runtime.straggler import StepTimeMonitor


@dataclasses.dataclass
class TrainResult:
    state: dict
    losses: list
    restarts: int
    steps_run: int
    #: per-phase stall breakdown (seconds): where the step wall time went
    stalls: dict = dataclasses.field(default_factory=dict)
    #: accumulated two-level data-path stats across all loaders of the run
    loader_stats: dict = dataclasses.field(default_factory=dict)


def run_training(
    cfg,
    store: TwoLevelStore,
    total_steps: int,
    global_batch: int = 8,
    seq_len: int = 64,
    ckpt_every: int = 5,
    ckpt_mode: str = "async",
    peak_lr: float = 1e-3,
    injector: FailureInjector | None = None,
    max_restarts: int = 8,
    heartbeat_timeout: float = 300.0,
    on_step: Callable[[int, dict], None] | None = None,
    accum_steps: int = 1,
) -> TrainResult:
    """Train with checkpoint/restart through the two-level store."""
    model = make_model(cfg)
    optimizer = AdamW(learning_rate=cosine_warmup(peak_lr, 10, max(total_steps, 20)))
    train_step = jax.jit(make_train_step(model, cfg, optimizer, accum_steps=accum_steps))

    corpus = SyntheticCorpus(
        store, vocab_size=cfg.vocab, n_shards=8,
        tokens_per_shard=max(global_batch * (seq_len + 1) * 4, 1 << 14),
    )
    corpus.generate()
    ckpt = CheckpointManager(store, tag=cfg.name, mode=ckpt_mode, keep_last=2)
    injector = injector or FailureInjector()
    # One monitor per step phase: total step time, time stalled on the data
    # plane (next(loader)), and time stalled on the checkpoint critical path
    # (cursor sync + save).  In async mode the save stall is the device_get
    # snapshot only — serialization and store puts run off the step path.
    monitor = StepTimeMonitor(n_hosts=1)
    data_monitor = StepTimeMonitor(n_hosts=1)
    ckpt_monitor = StepTimeMonitor(n_hosts=1)
    data_stall_s = ckpt_stall_s = 0.0
    agg_loader: dict[str, float] = {}

    def fold_loader_stats(loader: ShardedLoader) -> None:
        for k, v in dataclasses.asdict(loader.stats).items():
            agg_loader[k] = agg_loader.get(k, 0) + v

    def fresh_state():
        state, _ = init_state(model, cfg, optimizer, jax.random.PRNGKey(0))
        state["pipeline"] = {"epoch": np.int64(0), "step": np.int64(0)}
        return state

    state = fresh_state()
    if ckpt.latest_step() is not None:
        _, state = ckpt.restore(state)

    losses: list = []
    restarts = 0
    steps_run = 0

    try:
        with Heartbeat(timeout_s=heartbeat_timeout) as hb:
            while True:
                pstate = PipelineState(
                    int(state["pipeline"]["epoch"]), int(state["pipeline"]["step"])
                )
                loader = ShardedLoader(
                    corpus, global_batch, seq_len, prefetch_depth=2, state=pstate
                )
                try:
                    while int(state["step"]) < total_steps:
                        step_no = int(state["step"])
                        injector.maybe_fail(step_no)
                        t0 = time.perf_counter()
                        inputs, labels = next(loader)
                        t_data = time.perf_counter() - t0
                        batch = {"inputs": jnp.asarray(inputs), "labels": jnp.asarray(labels)}
                        state, metrics = train_step(state, batch)
                        hb.beat()
                        loss = float(metrics["loss"])
                        losses.append(loss)
                        steps_run += 1
                        if on_step:
                            on_step(step_no, metrics)
                        t_ckpt = 0.0
                        if int(state["step"]) % ckpt_every == 0:
                            tc = time.perf_counter()
                            cursor = loader.sync()
                            state["pipeline"] = {
                                "epoch": np.int64(cursor.epoch),
                                "step": np.int64(cursor.step),
                            }
                            ckpt.save(int(state["step"]), state)
                            t_ckpt = time.perf_counter() - tc
                        monitor.record({0: time.perf_counter() - t0})
                        data_monitor.record({0: t_data})
                        ckpt_monitor.record({0: t_ckpt})
                        data_stall_s += t_data
                        ckpt_stall_s += t_ckpt
                    break  # completed
                except SimulatedFailure:
                    restarts += 1
                    if restarts > max_restarts:
                        raise
                    # Recovery: last committed two-level checkpoint (memory-
                    # tier hit when the tier survived; PFS read mode (f)
                    # otherwise).
                    state = fresh_state()
                    if ckpt.latest_step() is not None:
                        _, state = ckpt.restore(state)
                finally:
                    loader.close()
                    fold_loader_stats(loader)

        ckpt.wait_until_durable()
    finally:
        ckpt.close()  # stop the background save lane (joins pending saves)
    stalls = {
        "step_ewma_s": monitor.synchronous_step_time(),
        "data_stall_ewma_s": data_monitor.synchronous_step_time(),
        "ckpt_stall_ewma_s": ckpt_monitor.synchronous_step_time(),
        "data_stall_total_s": data_stall_s,
        "ckpt_stall_total_s": ckpt_stall_s,
        "ckpt_save_critical_s": sum(ckpt.save_critical_s),
    }
    return TrainResult(
        state=state,
        losses=losses,
        restarts=restarts,
        steps_run=steps_run,
        stalls=stalls,
        loader_stats=agg_loader,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--store", default="/tmp/repro_store")
    ap.add_argument("--ckpt-mode", default="async", choices=["sync", "async", "memory_only"])
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--distributed", action="store_true",
                    help="join --store as a DistributedStore host shard (leases, peer "
                         "reads, background reclamation)")
    ap.add_argument("--host-id", type=int, default=1,
                    help="host id for --distributed (unique per process)")
    ap.add_argument("--lease-ttl", type=float, default=5.0,
                    help="heartbeat/lease ttl seconds for --distributed")
    ap.add_argument("--chaos", nargs="*", default=[], metavar="SITE:KIND[,k=v...]",
                    help="arm chaos faults, e.g. peer.request:delay,prob=0.2,delay_s=0.05 "
                         "(see repro.runtime.failure.ChaosInjector)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    chaos = None
    if args.chaos:
        from repro.runtime.failure import ChaosInjector

        chaos = ChaosInjector.from_specs(args.chaos, seed=args.chaos_seed)
    store_kw = dict(mem_capacity_bytes=256 * 2**20, block_bytes=4 * 2**20)
    dstore = None
    if args.distributed:
        from repro.core.dstore import DistributedStore

        dstore = DistributedStore(
            args.host_id, args.store, lease_ttl_s=args.lease_ttl, chaos=chaos, **store_kw
        )
        store = dstore.store  # training I/O runs this shard's local data path
    else:
        store = TwoLevelStore(args.store, chaos=chaos, **store_kw)
    try:
        res = run_training(
            cfg,
            store,
            total_steps=args.steps,
            global_batch=args.batch,
            seq_len=args.seq,
            ckpt_mode=args.ckpt_mode,
            injector=FailureInjector(args.fail_at),
            on_step=lambda s, m: print(f"step {s:4d} loss {float(m['loss']):.4f}"),
        )
    finally:
        if dstore is not None:
            dstore.close()
        else:
            store.close()
    print(
        f"done: {res.steps_run} steps run ({res.restarts} restarts), "
        f"final loss {res.losses[-1]:.4f}"
    )
    print(
        f"stalls: data {res.stalls['data_stall_total_s']:.2f}s, "
        f"ckpt {res.stalls['ckpt_stall_total_s']:.2f}s "
        f"(save critical path {res.stalls['ckpt_save_critical_s']:.2f}s)"
    )
    if dstore is not None:
        st = dstore.stats
        print(
            f"dstore[h{dstore.host_id}]: {st.lease_claims} leases "
            f"({st.takeovers} takeovers, {st.reclaimed_files} reclaimed in "
            f"{st.reclaim_ticks} ticks), {st.peer_retries} peer retries, "
            f"{st.peer_reconnects} reconnects, {st.cold_fallback_reads} cold fallbacks"
        )
    if chaos is not None:
        print(f"chaos: {chaos.fired_count()} faults fired ({len(chaos.history)} events)")


if __name__ == "__main__":
    main()
