import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any jax import: jax locks the device
count at first init, and the production meshes need 512 host-platform
placeholder devices (16x16 single-pod and 2x16x16 dual-pod).

Per cell this driver:
  1. builds the abstract train state / caches (ShapeDtypeStruct only),
  2. resolves shardings via the shard-if-divisible rules,
  3. ``jit(step).lower(...)`` then ``.compile()`` under the mesh,
  4. records ``memory_analysis()``, ``cost_analysis()`` and the summed
     collective bytes parsed from the optimized HLO,
  5. writes JSON to ``benchmarks/out/dryrun/`` for §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all [--force]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, applicable_shapes, get_config, make_model
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    batch_shardings,
    batch_specs,
    cache_shardings,
    cache_specs,
    init_state,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    state_shardings,
    token_specs,
)
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.nn.module import axis_rules
from repro.optim.adamw import AdamW

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "out", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+\[[\d,]*\])"  # first output shape
    r".*?\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)


def _shape_bytes(text: str) -> int:
    m = _SHAPE_RE.match(text)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in optimized HLO.

    Output-shape bytes is the documented proxy for payload (all-reduce:
    full tensor; all-gather: gathered tensor; reduce-scatter: shard).
    """
    per_type: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # Skip -done ops (the -start carries the shape) and parameter lines.
        if "-done" in stripped:
            continue
        for coll in _COLLECTIVES:
            token = f" {coll}(" if f" {coll}(" in stripped else f" {coll}-start("
            if token in stripped and "=" in stripped:
                lhs = stripped.split("=", 1)[1].strip()
                # tuple outputs: take all shapes in the leading tuple
                if lhs.startswith("("):
                    shapes = _SHAPE_RE.findall(lhs[: lhs.index(")")])
                    nbytes = 0
                    for dt, dims in shapes:
                        n = 1
                        for d in dims.split(","):
                            if d:
                                n *= int(d)
                        nbytes += n * _DTYPE_BYTES.get(dt, 4)
                else:
                    nbytes = _shape_bytes(lhs)
                per_type[coll] += nbytes
                counts[coll] += 1
                break
    return {
        "bytes_by_type": per_type,
        "counts": counts,
        "total_bytes": sum(per_type.values()),
    }


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    seq_shard: bool = False,
    seq_parallel: bool = False,
    remat: str | None = None,
    rules_name: str = "default",
    dp: int | None = None,
) -> dict:
    import dataclasses

    from repro.nn.module import RULE_SETS

    cfg = get_config(arch)
    if seq_parallel:
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    rules = RULE_SETS[rules_name]
    cell = SHAPES[shape_name]
    model = make_model(cfg)
    if dp is not None:
        # perf-variant mesh: same 256 chips, different dp x tp split
        from repro.launch.mesh import _mk

        if 256 % dp:
            raise ValueError(f"dp={dp} must divide 256")
        mesh = _mk((dp, 256 // dp), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    optimizer = AdamW()
    t0 = time.time()

    with mesh, axis_rules(mesh, rules):
        state, axes = init_state(model, cfg, optimizer, jax.random.PRNGKey(0), abstract=True)
        st_sh = state_shardings(state, axes, mesh, rules)

        if cell.kind == "train":
            bspec = batch_specs(cfg, cell)
            b_sh = batch_shardings(bspec, mesh)
            step = make_train_step(model, cfg, optimizer)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None)).lower(
                state, bspec
            )
        elif cell.kind == "prefill":
            bspec = batch_specs(cfg, cell)
            b_sh = batch_shardings(bspec, mesh)
            cspec = jax.eval_shape(lambda: model.init_caches(cell.global_batch, cell.seq_len, jnp.dtype(cfg.dtype)))
            c_sh = cache_shardings(cspec, cfg, mesh, seq_shard=seq_shard)
            step = make_prefill_step(model, cfg)
            lowered = jax.jit(
                step, in_shardings=(st_sh["params"], b_sh, c_sh), out_shardings=None
            ).lower(state["params"], bspec, cspec)
        else:  # decode
            tspec = token_specs(cfg, cell)
            t_sh = batch_shardings(tspec, mesh)
            cspec = cache_specs(model, cfg, cell)
            c_sh = cache_shardings(cspec, cfg, mesh, seq_shard=seq_shard)
            step = make_serve_step(model, cfg)
            lowered = jax.jit(
                step, in_shardings=(st_sh["params"], t_sh, c_sh), out_shardings=(t_sh, c_sh)
            ).lower(state["params"], tspec, cspec)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # Trip-count-aware analysis: XLA cost_analysis counts while bodies once;
    # scan-over-layers models need the corrected numbers for §Roofline.
    corrected = hlo_analyze(hlo)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": cell.kind,
        "seq_shard": seq_shard,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(compiled),
        "cost": _cost_dict(compiled),
        "collectives": coll,
        "corrected": {
            "dot_flops": corrected.corrected_dot_flops,
            "raw_dot_flops": corrected.raw_dot_flops,
            "coll_bytes_by_type": corrected.corrected_coll_bytes,
            "coll_counts": corrected.corrected_coll_counts,
            "coll_total_bytes": corrected.total_coll_bytes,
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    return result


def cell_path(arch: str, shape_name: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "multi" if multi_pod else "single"
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--seq-shard", action="store_true", help="shard cache seq dim (perf variant)")
    ap.add_argument("--seq-parallel", action="store_true", help="sequence-parallel residual (perf variant)")
    ap.add_argument("--remat", default=None, choices=["none", "full", "dots"], help="override remat policy")
    ap.add_argument("--rules", default="default", choices=["default", "fsdp"], help="sharding rule set")
    ap.add_argument("--dp", type=int, default=None, help="override dp size (single-pod perf variant)")
    ap.add_argument("--tag", default="", help="suffix for output JSON (perf variants)")
    args = ap.parse_args()

    if args.all:
        archs = ARCH_IDS
    elif args.arch:
        archs = [args.arch.replace("-", "_")]
    else:
        ap.error("--arch or --all required")

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else [c.name for c in applicable_shapes(cfg)]
        for shape_name in shapes:
            for multi in meshes:
                path = cell_path(arch, shape_name, multi, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {path}")
                    continue
                label = f"{arch} x {shape_name} x {'2x16x16' if multi else '16x16'}"
                print(f"[dryrun] {label} ...", flush=True)
                try:
                    res = run_cell(
                        arch, shape_name, multi,
                        seq_shard=args.seq_shard, seq_parallel=args.seq_parallel,
                        remat=args.remat, rules_name=args.rules, dp=args.dp,
                    )
                    with open(path, "w") as fh:
                        json.dump(res, fh, indent=2)
                    c = res["cost"]
                    print(
                        f"[ok] {label}: compile={res['compile_s']}s "
                        f"flops={c.get('flops', float('nan')):.3e} "
                        f"coll={res['collectives']['total_bytes']:.3e}B",
                        flush=True,
                    )
                except Exception as e:
                    failures.append((label, repr(e)))
                    traceback.print_exc()
                    print(f"[FAIL] {label}: {e}", flush=True)

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for label, err in failures:
            print(f"  {label}: {err[:200]}")
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
