"""Blocked gated-linear-recurrence kernel (RG-LRU core, Pallas TPU).

Computes ``h_t = a_t * h_{t-1} + x_t`` over the sequence axis.

Tiling: grid = (B, W/block_w, S/block_s); the sequence axis is the
sequential grid dimension, carrying ``h`` in VMEM scratch between tiles.
Within a (block_s, block_w) tile the recurrence closes in log2(block_s)
Hillis-Steele passes — each pass is a full-width vector op, so the MXU/VPU
stays busy instead of serializing one timestep at a time; the carry-in
folds as ``h_t += A_cum_t * h0``.

This is the HBM-bandwidth-bound op of the hybrid archs: the roofline
memory term is ~3 streams (a, x, h) x S x W bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams in 0.6; support both.
_compiler_params = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _rglru_kernel(a_ref, x_ref, o_ref, h_scr, *, block_s: int):
    is_ = pl.program_id(2)

    @pl.when(is_ == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)  # (bs, bw)
    x = x_ref[0].astype(jnp.float32)

    # Hillis-Steele inclusive scan of the affine maps (a, x):
    #   (a2, x2) o (a1, x1) = (a1*a2, a2*x1 + x2)
    acc_a, acc_x = a, x
    shift = 1
    while shift < block_s:
        a_sh = jnp.pad(acc_a, ((shift, 0), (0, 0)), constant_values=1.0)[:block_s]
        x_sh = jnp.pad(acc_x, ((shift, 0), (0, 0)), constant_values=0.0)[:block_s]
        acc_x = acc_x + acc_a * x_sh
        acc_a = acc_a * a_sh
        shift *= 2

    h0 = h_scr[0]  # (bw,) carry from previous sequence tile
    h_all = acc_x + acc_a * h0[None, :]
    o_ref[0] = h_all.astype(o_ref.dtype)
    h_scr[...] = jnp.broadcast_to(h_all[-1], h_scr.shape)


def rglru_scan_fwd(
    a: jax.Array,  # (B, S, W) decay in (0,1]
    x: jax.Array,  # (B, S, W) gated input
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, s, w = a.shape
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    if s % block_s or w % block_w:
        s_pad = -(-s // block_s) * block_s
        w_pad = -(-w // block_w) * block_w
        # pad a with 1s would corrupt carry; pad with 0 decay + 0 input: the
        # padded steps write h=0 but only padded rows read them -> safe, and
        # padded width lanes are sliced off.
        a = jnp.pad(a, ((0, 0), (0, s_pad - s), (0, w_pad - w)))
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, w_pad - w)))
        s2, w2 = s_pad, w_pad
    else:
        s2, w2 = s, w

    grid = (b, w2 // block_w, s2 // block_s)
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda ib, iw, is_: (ib, is_, iw)),
            pl.BlockSpec((1, block_s, block_w), lambda ib, iw, is_: (ib, is_, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda ib, iw, is_: (ib, is_, iw)),
        out_shape=jax.ShapeDtypeStruct((b, s2, w2), x.dtype),
        scratch_shapes=[pltpu.VMEM((8, block_w), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, x)
    return out[:, :s, :w]
