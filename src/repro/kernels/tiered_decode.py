"""Two-level decode attention (Pallas TPU) — the paper's tiered read path
materialized at the VMEM/HBM level (DESIGN.md §2, row L3).

Decode attention is memory-bound: every step streams the whole KV cache
through the chip.  The paper's insight — put a small fast tier in front
of the big slow tier and blend reads (Eq. 7) — maps onto TPU decode as:

    hot tier  = the last ``W`` tokens' KV, kept VMEM-resident across the
                whole kernel (BlockSpec index constant in the streaming
                axis -> fetched once, like Tachyon's RAM blocks);
    cold tier = the paged history, streamed tile-by-tile from HBM
                (the OrangeFS analogue).

The kernel is **ring-aware** and **length-dynamic**:

* The hot tier is consumed as the raw ring buffer — no caller-side
  chronological gather.  Decode softmax is permutation-invariant over
  valid keys, so the ring rotation reduces to position arithmetic: slot
  ``j`` has age ``(newest_slot - j) mod W`` and is valid iff
  ``age < hot_len``.  A caller with a plain chronological buffer passes
  ``newest_slot = hot_len - 1`` and gets the old ``j < hot_len`` mask.
* ``hot_len`` / ``cold_len`` / ``newest_slot`` arrive via scalar
  prefetch (SMEM), not as trace-time constants — one compiled kernel
  serves every decode step instead of retracing as the history grows.
* The cold tier is a paged buffer whose capacity is a ``block_k``
  multiple; the trailing partial page is masked by ``cold_len``.  The
  caller never ``jnp.pad``s the history per call — blocks past
  ``cold_len`` are skipped via ``pl.when`` on the prefetched scalar.

The kernel merges both tiers with one online softmax.  The effective
read time follows the paper's harmonic model with
``f = hot_len / (hot_len + cold_len)`` and rates (VMEM bw, HBM bw) — the
benchmark in ``benchmarks/fig5_crossover.py`` reuses Eq. 7 with TPU
constants for exactly this kernel.

Layout: q (B, H, 1, D) — a decode step; cold (B, KV, C, D) HBM-streamed
paged capacity buffer; hot (B, KV, W, D) VMEM-pinned ring.  Key order is
[cold ; hot] (softmax-order irrelevant, kept for the docs' mental model).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams in 0.6; support both.
_compiler_params = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30
LANES = 128
SUBLANES = 8


def _tiered_kernel(
    lens_ref,  # SMEM (3,): [hot_len, cold_len, newest_slot]
    q_ref,
    hot_k_ref,
    hot_v_ref,
    cold_k_ref,
    cold_v_ref,
    o_ref,
    acc_scr,
    m_scr,
    l_scr,
    *,
    sm_scale: float,
    block_k: int,
    w_max: int,
):
    ik = pl.program_id(1)
    n_k = pl.num_programs(1)
    hot_len = lens_ref[0]
    cold_len = lens_ref[1]
    newest = lens_ref[2]

    q = q_ref[0].astype(jnp.float32)  # (SUBLANES, D) row-broadcast query

    @pl.when(ik == 0)
    def _hot():
        # Fast tier first — the paper's 'nearest available copy' priority.
        hk = hot_k_ref[0].astype(jnp.float32)  # (W, D)
        hv = hot_v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, hk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        s = s * sm_scale  # (SUBLANES, W)
        # Ring validity by age: slot j holds the (newest - j mod W)-th most
        # recent token; the shift keeps the rem argument non-negative.
        slot = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, w_max), 1)
        age = jax.lax.rem(newest - slot + w_max, w_max)
        s = jnp.where(age < hot_len, s, NEG_INF)
        m = jnp.max(s, axis=1, keepdims=True)
        p = jnp.exp(s - m)
        p = jnp.where(age < hot_len, p, 0.0)  # exact zero when fully masked
        acc_scr[...] = jax.lax.dot_general(
            p, hv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        l_scr[...] = jnp.broadcast_to(jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m, m_scr.shape)

    k0 = ik * block_k

    @pl.when(k0 < cold_len)
    def _cold():
        ck = cold_k_ref[0].astype(jnp.float32)  # (bk, D)
        cv = cold_v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, ck, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        s = s * sm_scale
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, block_k), 1)
        s = jnp.where(kpos < cold_len, s, NEG_INF)
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(kpos < cold_len, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True), l_scr.shape
        )
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, cv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def tiered_decode_attention_fwd(
    q: jax.Array,  # (B, H, 1, D)
    hot_k: jax.Array,  # (B, KV, W, D) fast tier (ring buffer of recent keys)
    hot_v: jax.Array,
    cold_k: jax.Array,  # (B, KV, C, D) cold tier paged capacity buffer
    cold_v: jax.Array,
    lens: jax.Array,  # (3,) int32: [hot_len, cold_len, newest_slot]
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, one, d = q.shape
    _, kv, w_max, _ = hot_k.shape
    t = cold_k.shape[2]
    g = h // kv
    block_k = min(block_k, t)
    if t % block_k:
        # Fallback for ad-hoc callers; the paged serving cache always hands
        # over a block-multiple capacity buffer, so serving never pads.
        pad = -(-t // block_k) * block_k - t
        cold_k = jnp.pad(cold_k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cold_v = jnp.pad(cold_v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        t = cold_k.shape[2]

    # Broadcast the single query row across sublanes for layout friendliness.
    qf = jnp.broadcast_to(q.reshape(b * h, 1, d), (b * h, SUBLANES, d))

    grid = (b * h, t // block_k)
    kvmap = lambda bh, ik, lens, kv=kv, h=h, g=g: (bh // h * kv + (bh % h) // g, 0, 0)
    kvmap_cold = lambda bh, ik, lens, kv=kv, h=h, g=g: (bh // h * kv + (bh % h) // g, ik, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, SUBLANES, d), lambda bh, ik, lens: (bh, 0, 0)),
            # hot tier: block index constant across the streaming axis ->
            # fetched into VMEM once per (b, h) program (the fast tier).
            pl.BlockSpec((1, w_max, d), kvmap),
            pl.BlockSpec((1, w_max, d), kvmap),
            pl.BlockSpec((1, block_k, d), kvmap_cold),
            pl.BlockSpec((1, block_k, d), kvmap_cold),
        ],
        out_specs=pl.BlockSpec((1, SUBLANES, d), lambda bh, ik, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((SUBLANES, d), jnp.float32),
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
            pltpu.VMEM((SUBLANES, LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _tiered_kernel,
            sm_scale=1.0 / (d**0.5),
            block_k=block_k,
            w_max=w_max,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, SUBLANES, d), q.dtype),
        compiler_params=_compiler_params(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens.astype(jnp.int32), qf,
      hot_k.reshape(b * kv, w_max, d), hot_v.reshape(b * kv, w_max, d),
      cold_k.reshape(b * kv, t, d), cold_v.reshape(b * kv, t, d))

    return out[:, :1, :].reshape(b, h, 1, d)
