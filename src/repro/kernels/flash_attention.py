"""Flash attention (Pallas TPU): causal / sliding-window / GQA.

Tiling: grid = (B*H, S/block_q, T/block_k); the key axis is the
sequential ("arbitrary") dimension so the online-softmax running state
(m, l, acc) lives in VMEM scratch across key tiles.  Blocks:

    q   (1, block_q, D)  VMEM     o (1, block_q, D) VMEM (written at last tile)
    k,v (1, block_k, D)  VMEM     scratch: acc (bq, D) f32, m/l (bq, 128) f32

MXU alignment: block_q/block_k default 128; D is the head dim (128/256
for the assigned archs).  Fully-masked key tiles are skipped via
``pl.when`` on scalar tile bounds — with causal masking this halves the
compute; with a sliding window only O(window/block_k) tiles run per row
(the sub-quadratic path used by gemma3/recurrentgemma).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams in 0.6; support both.
_compiler_params = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30
LANES = 128


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_scr,
    m_scr,
    l_scr,
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    logit_softcap: float,
    block_q: int,
    block_k: int,
    s_real: int,
    t_real: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q0 = iq * block_q
    k0 = ik * block_k
    offset = t_real - s_real  # right-aligned causality when T > S

    needed = k0 < t_real
    if causal:
        needed &= k0 <= q0 + offset + block_q - 1
    if window > 0:
        needed &= k0 + block_k - 1 > q0 + offset - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        s = s * sm_scale
        if logit_softcap > 0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + offset
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < t_real
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, T, D)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    _, kv, t, _ = k.shape
    if h % kv:
        raise ValueError(f"H={h} not a multiple of KV={kv}")
    g = h // kv

    block_q = min(block_q, max(s, 8))
    block_k = min(block_k, max(t, 8))
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - s), (0, 0))) if s_pad != s else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0))) if t_pad != t else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0))) if t_pad != t else v

    qf = qp.reshape(b * h, s_pad, d)
    kf = kp.reshape(b * kv, t_pad, d)
    vf = vp.reshape(b * kv, t_pad, d)

    grid = (b * h, s_pad // block_q, t_pad // block_k)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=1.0 / (d**0.5),
        causal=causal,
        window=window,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_k=block_k,
        s_real=s,
        t_real=t,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik, g=g, kv=kv, h=h: (bh // h * kv + (bh % h) // g, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik, g=g, kv=kv, h=h: (bh // h * kv + (bh % h) // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qf, kf, vf)

    return out.reshape(b, h, s_pad, d)[:, :, :s, :]
