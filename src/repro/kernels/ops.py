"""Public jit'd wrappers around the Pallas kernels.

Each op auto-selects ``interpret=True`` off-TPU (this container is
CPU-only; the kernels execute their bodies in the Pallas interpreter for
correctness validation) and compiles natively on a TPU backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mlstm import mlstm_chunkwise_fwd
from repro.kernels.rglru import rglru_scan_fwd
from repro.kernels.tiered_decode import tiered_decode_attention_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logit_softcap", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Tiled attention. q: (B,H,S,D); k,v: (B,KV,T,D) -> (B,H,S,D)."""
    interpret = _interpret_default() if interpret is None else interpret
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def rglru_scan_op(
    a: jax.Array,
    x: jax.Array,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """h_t = a_t h_{t-1} + x_t over axis 1. a, x: (B,S,W) -> (B,S,W)."""
    interpret = _interpret_default() if interpret is None else interpret
    return rglru_scan_fwd(a, x, block_s=block_s, block_w=block_w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,
    f_log: jax.Array,
    chunk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunkwise mLSTM. q,k,v: (B,H,S,D); gates: (B,H,S) -> (B,H,S,D)."""
    interpret = _interpret_default() if interpret is None else interpret
    return mlstm_chunkwise_fwd(q, k, v, i_pre, f_log, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def _tiered_decode_jit(q, hot_k, hot_v, cold_k, cold_v, lens, block_k, interpret):
    return tiered_decode_attention_fwd(
        q, hot_k, hot_v, cold_k, cold_v, lens, block_k=block_k, interpret=interpret
    )


def tiered_decode_attention(
    q: jax.Array,
    hot_k: jax.Array,
    hot_v: jax.Array,
    cold_k: jax.Array,
    cold_v: jax.Array,
    hot_len: jax.Array | int,
    cold_len: jax.Array | int,
    ring_newest: jax.Array | int | None = None,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Two-tier decode attention; key order [cold ; hot] (DESIGN.md L3).

    ``hot_len``/``cold_len``/``ring_newest`` are *dynamic* (scalar-prefetch
    operands) — one compiled kernel serves every decode step, instead of
    retracing as the history grows.  ``ring_newest`` is the hot-ring slot
    of the most recent token; ``None`` means the hot buffer is plain
    chronological (valid slots ``[0, hot_len)``).
    """
    interpret = _interpret_default() if interpret is None else interpret
    if ring_newest is None:
        ring_newest = hot_len - 1
    parts = (hot_len, cold_len, ring_newest)
    if all(isinstance(p, int) for p in parts):
        lens = np.asarray(parts, np.int32)  # one transfer, no eager stack
    else:
        lens = jnp.stack([jnp.asarray(p, jnp.int32) for p in parts])
    return _tiered_decode_jit(
        q, hot_k, hot_v, cold_k, cold_v, lens, block_k=block_k, interpret=interpret
    )
