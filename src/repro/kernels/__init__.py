"""Pallas TPU kernels for the compute hot-spots, with pure-jnp oracles.

Layout per kernel family:
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py     — jit'd public wrappers (dispatch kernel vs. xla path)
    ref.py     — pure-jnp oracles the tests assert against

Kernels:
    flash_attention         train/prefill attention (causal, GQA, window)
    rglru                   blocked gated-linear-recurrence scan
    mlstm                   chunkwise-parallel mLSTM (matrix memory)
    tiered_decode_attention two-level (hot VMEM / cold HBM) decode attention
                            — the paper's two-tier read path in kernel form;
                            ring-aware (hot tier consumed as a ring buffer)
                            with dynamic lengths via scalar prefetch, so one
                            trace serves a whole decode
"""

from repro.kernels.ops import (
    flash_attention,
    mlstm_chunkwise,
    rglru_scan_op,
    tiered_decode_attention,
)

__all__ = [
    "flash_attention",
    "mlstm_chunkwise",
    "rglru_scan_op",
    "tiered_decode_attention",
]
