"""Chunkwise-parallel mLSTM kernel (Pallas TPU).

The mLSTM matrix memory ``C_t = f_t C_{t-1} + i_t v_t k_t^T`` is a linear
recurrence over (D, D) states with exponential gating and a max
stabilizer ``m``.  Sequential scan is VPU-serial; the chunkwise form
closes a chunk of ``C`` timesteps with dense (C,C)/(C,D) matmuls and
carries only (C_mat, n, m) between chunks — MXU-friendly, the same trick
flash attention plays with online softmax.

Grid = (B*H, S/chunk), sequential over chunks; carries live in VMEM
scratch: C_mat (D, D) f32, n (8, D) f32 (row-broadcast), m (8, 128) f32.

Stabilized chunk math (l <= j within the chunk; b = cumsum(f_log)):

    w_jl      = b_j - b_l + g_l
    m_intra_j = max_l w_jl ;  m_inter_j = m_prev + b_j
    m_j       = max(m_intra_j, m_inter_j)
    num_j     = e^{m_inter_j - m_j} (C_prev q_j)
                + sum_l e^{w_jl - m_j} (k_l . q_j) v_l
    n_j       = e^{m_inter_j - m_j} n_prev + sum_l e^{w_jl - m_j} k_l
    h_j       = num_j / max(|n_j . q_j|, 1)

Chunk-end carry uses the same formulas at j = C with stabilizer
``m_next = max(m_prev + b_C, max_l (b_C - b_l + g_l))``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams in 0.6; support both.
_compiler_params = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, g_ref, f_ref, o_ref, cmat_scr, n_scr, m_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        cmat_scr[...] = jnp.zeros_like(cmat_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0].astype(jnp.float32)  # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    g = g_ref[0, :, 0].astype(jnp.float32)  # (C,) log input gate
    f = f_ref[0, :, 0].astype(jnp.float32)  # (C,) log forget gate

    b = jnp.cumsum(f)  # (C,)
    m_prev = m_scr[0, 0]
    c_prev = cmat_scr[...]
    n_prev = n_scr[0]

    # intra-chunk decay matrix
    w = b[:, None] - b[None, :] + g[None, :]  # (C, C)
    ltri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 0
    )
    w = jnp.where(ltri, w, NEG_INF)
    m_intra = jnp.max(w, axis=1)  # (C,)
    m_inter = m_prev + b
    m_j = jnp.maximum(m_intra, m_inter)

    d_mat = jnp.exp(w - m_j[:, None])  # (C, C) masked decays
    inter_scale = jnp.exp(jnp.clip(m_inter - m_j, None, 0.0))  # (C,)
    # m_prev == -inf (first chunk): inter contribution is exactly zero
    inter_scale = jnp.where(jnp.isinf(m_prev), 0.0, inter_scale)

    s_qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    # cmat layout is (Dk, Dv) — contract q's key dim against cmat dim 0.
    num = inter_scale[:, None] * jax.lax.dot_general(
        q, c_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + jax.lax.dot_general(
        s_qk * d_mat, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_j = inter_scale[:, None] * n_prev[None, :] + jax.lax.dot_general(
        d_mat, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    denom = jnp.maximum(jnp.abs(jnp.sum(n_j * q, axis=1)), 1.0)
    o_ref[0] = (num / denom[:, None]).astype(o_ref.dtype)

    # ---- chunk-end carry ----
    btot = b[-1]
    wc = btot - b + g  # (C,)
    m_next = jnp.maximum(jnp.where(jnp.isinf(m_prev), NEG_INF, m_prev + btot), jnp.max(wc))
    carry_scale = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev + btot - m_next))
    kw = jnp.exp(wc - m_next)[:, None] * k  # (C, D) weighted keys
    cmat_scr[...] = carry_scale * c_prev + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_new = carry_scale * n_prev + jnp.sum(kw, axis=0)
    n_scr[...] = jnp.broadcast_to(n_new, n_scr.shape)
    m_scr[...] = jnp.full_like(m_scr, m_next)


def mlstm_chunkwise_fwd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, H, S) log input gate pre-activation
    f_log: jax.Array,  # (B, H, S) log-sigmoid forget gate
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"S={s} must be a multiple of chunk={chunk}")
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    # gates as (BH, S, 1) so BlockSpec stays rank-3
    gf = i_pre.reshape(bh, s, 1)
    ff = f_log.reshape(bh, s, 1)

    grid = (bh, s // chunk)
    out = pl.pallas_call(
        functools.partial(_mlstm_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, d), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, d), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda ib, ic: (ib, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((8, d), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
        ],
        compiler_params=_compiler_params(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, gf, ff)
    return out.reshape(b, h, s, d)
