"""Pure-jnp oracles for every kernel (the correctness ground truth).

Shapes follow the kernel conventions:
    attention   q: (B, H, S, D);  k, v: (B, KV, T, D)   (head-major)
    rglru       a, x: (B, S, W) -> h: (B, S, W)
    mlstm       q, k, v: (B, H, S, D); i, f pre-acts: (B, H, S)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Reference attention. GQA via KV-head broadcast. fp32 softmax."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    if logit_softcap > 0:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    t = k.shape[2]
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos + (t - s)  # right-aligned when t > s
    if window > 0:
        mask &= kpos > qpos + (t - s) - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v)
    return out.reshape(b, h, s, d)


def decode_attention_ref(
    q: jax.Array,  # (B, H, 1, D)
    k: jax.Array,  # (B, KV, T, D)
    v: jax.Array,
    length: jax.Array | int,  # number of valid keys
) -> jax.Array:
    b, h, _, d = q.shape
    kv = k.shape[1]
    g = h // kv
    t = k.shape[2]
    qg = q.reshape(b, kv, g, 1, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    valid = (jnp.arange(t) < length)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v)
    return out.reshape(b, h, 1, d)


def rglru_ref(a: jax.Array, x: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t, scanned over axis 1. fp32 accumulation."""
    b, s, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at.astype(jnp.float32) * h + xt.astype(jnp.float32)
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), x.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(x.dtype)


def mlstm_ref(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, H, S) log input gate pre-activation
    f_log: jax.Array,  # (B, H, S) log forget gate (log sigmoid already applied)
) -> jax.Array:
    """Sequential mLSTM with max-stabilizer (the recurrent ground truth)."""
    b, h, s, d = q.shape
    C0 = jnp.zeros((b, h, d, d), jnp.float32)
    n0 = jnp.zeros((b, h, d), jnp.float32)
    m0 = jnp.full((b, h), -jnp.inf, jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        no_hist = jnp.isinf(m) & (m < 0)
        m_safe = jnp.where(no_hist, 0.0, m)
        m_new = jnp.maximum(jnp.where(no_hist, it, ft + m_safe), it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.where(no_hist, 0.0, jnp.exp(ft + m_safe - m_new))
        C = f_g[..., None, None] * C + i_g[..., None, None] * (
            vt.astype(jnp.float32)[..., :, None] * kt.astype(jnp.float32)[..., None, :]
        )
        n = f_g[..., None] * n + i_g[..., None] * kt.astype(jnp.float32)
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt.astype(jnp.float32))), 1.0)
        ht = jnp.einsum("bhde,bhe->bhd", C, qt.astype(jnp.float32)) / denom[..., None]
        return (C, n, m_new), ht

    inputs = (
        q.transpose(2, 0, 1, 3),
        k.transpose(2, 0, 1, 3),
        v.transpose(2, 0, 1, 3),
        i_pre.transpose(2, 0, 1),
        f_log.transpose(2, 0, 1),
    )
    _, hs = jax.lax.scan(step, (C0, n0, m0), inputs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype)


def tiered_ring_attention_ref(
    q: jax.Array,  # (B, H, 1, D)
    hot_k: jax.Array,  # (B, KV, W, D) ring buffer (rotated order)
    hot_v: jax.Array,
    cold_k: jax.Array,  # (B, KV, C, D) paged capacity buffer
    cold_v: jax.Array,
    hot_len: jax.Array | int,
    cold_len: jax.Array | int,
    ring_newest: jax.Array | int,
) -> jax.Array:
    """Ring-aware two-tier decode oracle (mirrors ``tiered_decode_attention``).

    Hot slot ``j`` has age ``(ring_newest - j) mod W`` and is valid iff
    ``age < hot_len``; cold position ``t`` is valid iff ``t < cold_len``.
    Decode softmax is permutation-invariant over valid keys, so no
    chronological un-rotation of the ring is needed.  Fully jittable with
    dynamic lengths — also the XLA serving fallback off-TPU.
    """
    w = hot_k.shape[2]
    age = jnp.remainder(jnp.asarray(ring_newest, jnp.int32) - jnp.arange(w), w)
    hot_valid = age < hot_len
    cold_valid = jnp.arange(cold_k.shape[2]) < cold_len
    k = jnp.concatenate([cold_k, hot_k], axis=2)
    v = jnp.concatenate([cold_v, hot_v], axis=2)
    valid = jnp.concatenate([cold_valid, hot_valid])

    b, h, _, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, 1, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v)
    return out.reshape(b, h, 1, d)
