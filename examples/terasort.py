"""TeraSort on three storage organizations (the paper's Section 5.3
evaluation, miniaturized but moving real bytes) — now running on the
out-of-core shuffle engine, so ``--records`` may exceed the memory tier.

    PYTHONPATH=src python examples/terasort.py [--records 200000 --budget-mb 8]
"""

import argparse
import os
import tempfile

from repro.apps.terasort import teragen, terasort
from repro.core import IOController, ReadMode, TwoLevelStore, WriteMode

MB = 2**20

MODES = {
    "hdfs-like (memory only)": (WriteMode.MEMORY_ONLY, ReadMode.MEMORY_ONLY, WriteMode.MEMORY_ONLY),
    "orangefs (pfs bypass)": (WriteMode.PFS_BYPASS, ReadMode.PFS_BYPASS, WriteMode.PFS_BYPASS),
    "two-level (tiered)": (WriteMode.WRITE_THROUGH, ReadMode.TIERED, WriteMode.WRITE_THROUGH),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=200_000)
    ap.add_argument("--budget-mb", type=int, default=8,
                    help="engine sort budget; spills beyond it go through the store")
    args = ap.parse_args()

    print(f"TeraSort, {args.records:,} records x 100 B = {args.records * 100 / MB:.0f} MiB, "
          f"{args.budget_mb} MiB sort budget\n")
    print(f"{'storage':28s} {'gen(s)':>8s} {'map(s)':>8s} {'reduce(s)':>10s} "
          f"{'hit rate':>9s} {'spills':>7s}")
    results = {}
    reports = {}
    for label, (wgen, rmap, wred) in MODES.items():
        with tempfile.TemporaryDirectory() as d:
            ctl = IOController()  # adaptive control plane (DESIGN.md §10)
            with TwoLevelStore(
                os.path.join(d, "pfs"),
                mem_capacity_bytes=256 * MB,
                block_bytes=4 * MB,
                stripe_bytes=1 * MB,
                controller=ctl,
            ) as st:
                gen_s = teragen(st, args.records, n_shards=4, write_mode=wgen)
                t = terasort(
                    st,
                    n_shards=4,
                    n_reducers=4,
                    read_mode=rmap,
                    write_mode=wred,
                    label=label,
                    memory_budget_bytes=args.budget_mb * MB,
                )
                results[label] = t
                reports[label] = ctl.report()
                print(f"{label:28s} {gen_s:8.3f} {t.map_s:8.3f} {t.reduce_s:10.3f} "
                      f"{t.mem_hit_rate:9.2f} {t.spill_files:7d}")

    tls = results["two-level (tiered)"]
    ofs = results["orangefs (pfs bypass)"]
    print(f"\ntwo-level map phase vs orangefs: {ofs.map_s / tls.map_s:.2f}x "
          f"(paper measured 4.2x at cluster scale; mapper reads hit the memory tier)")
    print(f"external sort: {tls.spill_files} spill runs, k<={tls.merge_runs_max} merge, "
          f"peak buffers {tls.peak_buffer_bytes / MB:.1f} MiB, "
          f"{tls.shuffle_mbps:.1f} MB/s aggregate shuffle")
    print("output validated: globally ordered ✓")

    rep = reports["two-level (tiered)"]
    print("\nadaptive I/O controller (two-level run):")
    print(f"  admission: {rep['admits']} promoted / {rep['bypasses']} bypassed "
          f"(scan-class runs ghost-gated), {rep['flush_drops']} spill blocks flush-dropped")
    print(f"  readahead depths: {rep['readahead']}"
          + (f"; trajectory {[(c, dep) for _, c, dep in rep['readahead_trajectory'][-6:]]}"
             if rep['readahead_trajectory'] else ""))
    print(f"  flush lanes now {rep['flush_lanes']}"
          + (f", trajectory {[n for _, n in rep['lane_trajectory'][-8:]]}"
             if rep['lane_trajectory'] else ""))
    print(f"  model: nu={rep['nu_mbps']:.0f} q={rep['q_read_mbps']:.0f} MB/s; "
          f"measured f={rep['measured_f']:.3f} vs target f={rep['target_f']:.3f}; "
          f"predicted read {rep['predicted_read_mbps']:.0f} MB/s")


if __name__ == "__main__":
    main()
