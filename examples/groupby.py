"""Group-by/aggregate on the out-of-core shuffle engine.

The second workload on the external-sort shuffle (DESIGN.md §9): the
same spill/merge data path as TeraSort, with a reducer that collapses
each key's records into one (key, sum, count) aggregate row.

    PYTHONPATH=src python examples/groupby.py [--records 400000 --groups 5000]
"""

import argparse
import os
import tempfile

from repro.apps.groupby import groupby_sum, groupgen, read_aggregates
from repro.core import TwoLevelStore

MB = 2**20


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=400_000)
    ap.add_argument("--groups", type=int, default=5_000)
    ap.add_argument("--budget-mb", type=int, default=4)
    ap.add_argument("--mem-mb", type=int, default=8,
                    help="memory-tier capacity; default leaves the dataset cold")
    args = ap.parse_args()

    data_mb = args.records * 32 / MB
    print(f"group-by, {args.records:,} records x 32 B = {data_mb:.0f} MiB, "
          f"{args.groups:,} groups, {args.budget_mb} MiB sort budget\n")
    with tempfile.TemporaryDirectory() as d:
        with TwoLevelStore(
            os.path.join(d, "pfs"),
            mem_capacity_bytes=args.mem_mb * MB,
            block_bytes=1 * MB,
            stripe_bytes=1 * MB,
            n_pfs_servers=4,
            io_workers=8,
        ) as st:
            gen_s = groupgen(st, args.records, n_groups=args.groups, n_shards=4)
            res = groupby_sum(
                st,
                n_shards=4,
                n_reducers=4,
                memory_budget_bytes=args.budget_mb * MB,
            )
            aggs = read_aggregates(st, 4)
            s = res.stats
            print(f"gen          {gen_s:7.3f} s")
            print(f"sample       {s.sample_s:7.3f} s")
            print(f"map/spill    {s.spill_s:7.3f} s   "
                  f"({s.spill_batches} batches -> {s.spill_files} runs, "
                  f"{s.spill_bytes / MB:.1f} MiB spilled)")
            print(f"merge/agg    {s.merge_s:7.3f} s   (k<={s.runs_merged_max} ways)")
            print(f"groups       {res.groups:,} (readback: {len(aggs):,})")
            print(f"peak buffers {s.peak_buffer_bytes / MB:.2f} MiB "
                  f"(budget {args.budget_mb} MiB)")
            print(f"aggregate shuffle rate {s.aggregate_mbps():.1f} MB/s")
            total = sum(c for _, c in aggs.values())
            assert total == (args.records // 4) * 4, "lost records"
            print("\nall groups accounted for ✓")


if __name__ == "__main__":
    main()
