"""Batched serving: prefill a batch of prompts, decode greedily, report
per-phase throughput — then run the same workload through the two-level
KV cache (device hot ring + paged host cold tier, DESIGN.md §2a) and
report the measured serving-tier stats: hot fraction (the paper's
Eq. 7 f), staged H2D bytes per step (page-bounded, each page uploaded
once), and batched write-through flushes.

    PYTHONPATH=src python examples/serve_batch.py [--tokens 32]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced, make_model
from repro.core.cluster import ClusterSpec
from repro.core.iomodel import tls_read
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    tiered_cache_stats,
    tiered_serve_loop,
)
from repro.nn.module import init_with_axes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv-window", type=int, default=16,
                    help="two-level demo: hot-ring tokens (0 disables)")
    ap.add_argument("--kv-page", type=int, default=8,
                    help="two-level demo: cold staging page (tokens)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = make_model(cfg)
    params, _ = init_with_axes(model.init, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_len = args.prompt_len + args.tokens + 1
    caches = model.init_caches(args.batch, max_len, jnp.bfloat16)
    prefill = jax.jit(make_prefill_step(model, cfg))
    serve = jax.jit(make_serve_step(model, cfg))

    t0 = time.perf_counter()
    tok, caches = prefill(params, {"inputs": prompts}, caches)
    tok = tok[:, None]
    jax.block_until_ready(tok)
    prefill_s = time.perf_counter() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {prefill_s:.3f}s "
          f"({args.batch * args.prompt_len / prefill_s:,.0f} tok/s)")

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        tok, caches = serve(params, tok, caches)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode:  {args.tokens} steps x batch {args.batch} in {decode_s:.3f}s "
          f"({args.batch * args.tokens / decode_s:,.0f} tok/s)")
    print(f"sample continuation (row 0): {np.asarray(gen[0])[:16].tolist()}")

    # ---- the same workload through the two-level KV cache (measured) ----
    if args.kv_window > 0 and cfg.attn_logit_softcap == 0:
        ucfg = dataclasses.replace(cfg, scan_layers=False)
        umodel = make_model(ucfg)
        uparams, _ = init_with_axes(umodel.init, jax.random.PRNGKey(0), dtype=jnp.float32)
        gen2, _, tiered_s, tcaches = tiered_serve_loop(
            umodel, ucfg, uparams, prompts, args.tokens,
            window=args.kv_window, page=args.kv_page or None,
        )
        st = tiered_cache_stats(tcaches)
        if st["layers"]:
            steps = max(1, args.tokens)
            print(f"two-level KV ({st['layers']} full-attention layers, "
                  f"window {st['window']}, page {st['page']}):")
            print(f"  decode (eager loop): {steps} steps x batch {args.batch} in "
                  f"{tiered_s:.3f}s ({args.batch * steps / tiered_s:,.0f} tok/s)")
            print(f"  sample continuation (row 0): {np.asarray(gen2[0])[:16].tolist()} "
                  f"(independently initialized unrolled weights — not comparable "
                  f"token-for-token with the dense sample above; "
                  f"tests/test_serving.py gates equality under shared params)")
            print(f"  hot fraction f = {st['hot_fraction']:.3f} "
                  f"(the paper's Eq. 7 blend at context {st['length']})")
            print(f"  staged H2D: {st['bytes_staged'] / steps:,.0f} B/step "
                  f"({st['pages_staged']} pages, each uploaded exactly once)")
            print(f"  write-through: {st['bytes_written_through']:,} B in "
                  f"{st['d2h_flushes']} batched flushes "
                  f"(seed path: one sync per token)")
            print(f"  hot ring {st['hot_device_bytes']:,} B on device vs "
                  f"host tier {st['host_bytes']:,} B (cache dtype, not fp32)")
        else:
            print("two-level KV: no full-attention layers in this arch — skipped")

    # The decode-time two-tier read model (DESIGN.md §2a/L3): a hot window in
    # fast memory vs the cold KV history — Eq. 7 with TPU-class constants.
    vmem_like = ClusterSpec(
        name="tpu-decode-tiers", n_compute=1, n_data=1,
        backplane_mbps=1e12, nic_mbps=1e12,
        disk_read_mbps=1.0, disk_write_mbps=1.0,
        data_disk_read_mbps=819_000.0, data_disk_write_mbps=819_000.0,  # HBM
        ram_mbps=20_000_000.0,  # VMEM-class
    )
    total = args.prompt_len + args.tokens
    for window in (0, total // 2, total):
        f = window / total
        q = tls_read(vmem_like, f)
        print(f"  tiered-KV model: hot fraction f={f:.2f} -> effective read {q/1e6:.2f} TB/s")


if __name__ == "__main__":
    main()
