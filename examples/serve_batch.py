"""Batched serving: prefill a batch of prompts, decode greedily, report
per-phase throughput — plus the two-level KV-cache story at decode time
(hot ring vs cold history, the paper's read mode (f) in serving form).

    PYTHONPATH=src python examples/serve_batch.py [--tokens 32]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced, make_model
from repro.core.cluster import ClusterSpec
from repro.core.iomodel import tls_read
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.nn.module import init_with_axes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = make_model(cfg)
    params, _ = init_with_axes(model.init, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    max_len = args.prompt_len + args.tokens + 1
    caches = model.init_caches(args.batch, max_len, jnp.bfloat16)
    prefill = jax.jit(make_prefill_step(model, cfg))
    serve = jax.jit(make_serve_step(model, cfg))

    t0 = time.perf_counter()
    tok, caches = prefill(params, {"inputs": prompts}, caches)
    tok = tok[:, None]
    jax.block_until_ready(tok)
    prefill_s = time.perf_counter() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {prefill_s:.3f}s "
          f"({args.batch * args.prompt_len / prefill_s:,.0f} tok/s)")

    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        tok, caches = serve(params, tok, caches)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode:  {args.tokens} steps x batch {args.batch} in {decode_s:.3f}s "
          f"({args.batch * args.tokens / decode_s:,.0f} tok/s)")
    print(f"sample continuation (row 0): {np.asarray(gen[0])[:16].tolist()}")

    # The decode-time two-tier read model (DESIGN.md L2/L3): a hot window in
    # fast memory vs the cold KV history — Eq. 7 with TPU-class constants.
    vmem_like = ClusterSpec(
        name="tpu-decode-tiers", n_compute=1, n_data=1,
        backplane_mbps=1e12, nic_mbps=1e12,
        disk_read_mbps=1.0, disk_write_mbps=1.0,
        data_disk_read_mbps=819_000.0, data_disk_write_mbps=819_000.0,  # HBM
        ram_mbps=20_000_000.0,  # VMEM-class
    )
    total = args.prompt_len + args.tokens
    for window in (0, total // 2, total):
        f = window / total
        q = tls_read(vmem_like, f)
        print(f"  tiered-KV model: hot fraction f={f:.2f} -> effective read {q/1e6:.2f} TB/s")


if __name__ == "__main__":
    main()
