"""Quickstart: train a tiny LM whose data + checkpoints ride the
two-level storage system.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import tempfile

from repro.configs import get_reduced
from repro.core import TwoLevelStore
from repro.launch.train import run_training


def main() -> None:
    cfg = dataclasses.replace(
        get_reduced("qwen3_8b"), n_layers=2, d_model=64, d_ff=128, vocab=512
    )
    with tempfile.TemporaryDirectory() as d:
        # Memory tier (Tachyon analogue) + striped PFS tier (OrangeFS
        # analogue). Write-through: every block lands in both tiers.
        with TwoLevelStore(d + "/pfs", mem_capacity_bytes=64 * 2**20) as store:
            result = run_training(
                cfg,
                store,
                total_steps=10,
                ckpt_every=5,
                on_step=lambda s, m: print(f"  step {s:3d}  loss {float(m['loss']):.4f}"),
            )
            stats = store.tier_stats()
            print(f"\nfinished {result.steps_run} steps; final loss {result.losses[-1]:.4f}")
            print(f"memory-tier hit rate: {stats['store']['mem_hits']} hits / "
                  f"{stats['store']['mem_misses']} misses")
            print(f"PFS tier wrote {stats['pfs']['bytes_written']/2**20:.1f} MiB "
                  f"(checkpoints + corpus, CRC-protected stripes)")
            print(f"resident fraction f of the corpus: "
                  f"{store.resident_fraction('corpus/shard_00000'):.2f}")


if __name__ == "__main__":
    main()
