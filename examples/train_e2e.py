"""End-to-end resilient training driver.

Default: a ~15 M-param model, 30 steps, failure injected at step 12,
async two-level checkpoints — finishes in a couple of minutes on CPU.

--full: the ~100 M-param config for a few hundred steps (the deliverable
configuration; hours on CPU, minutes on a real accelerator host).

    PYTHONPATH=src python examples/train_e2e.py [--full]
"""

import argparse
import dataclasses
import tempfile
import time

from repro.configs.base import ArchConfig
from repro.core import IOController, TwoLevelStore
from repro.launch.train import run_training
from repro.runtime.failure import FailureInjector


def model_100m() -> ArchConfig:
    return ArchConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=32_768,
        attn_type="gqa",
        tie_embeddings=True,
        max_seq_len=2048,
        remat="none",
        dtype="float32",
    )


def model_15m() -> ArchConfig:
    return dataclasses.replace(
        model_100m(), name="repro-15m", n_layers=4, d_model=320, n_heads=8,
        n_kv_heads=8, d_ff=1280, vocab=8192,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params, 300 steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_15m()
    steps = args.steps or (300 if args.full else 30)
    batch = args.batch or (8 if args.full else 4)
    seq = args.seq or (512 if args.full else 128)
    fail_at = steps // 2

    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params; "
          f"{steps} steps of {batch}x{seq} tokens; failure injected at step {fail_at}")

    t0 = time.time()
    tokens_seen = 0

    def on_step(s, metrics):
        nonlocal tokens_seen
        tokens_seen += batch * seq
        if s % 5 == 0 or s == steps - 1:
            dt = time.time() - t0
            print(f"  step {s:4d}  loss {float(metrics['loss']):.4f}  "
                  f"{tokens_seen / max(dt, 1e-9):,.0f} tok/s")

    with tempfile.TemporaryDirectory() as d:
        ctl = IOController()  # adaptive I/O control plane (DESIGN.md §10)
        with TwoLevelStore(d + "/pfs", mem_capacity_bytes=512 * 2**20, block_bytes=4 * 2**20,
                           controller=ctl) as store:
            res = run_training(
                cfg,
                store,
                total_steps=steps,
                global_batch=batch,
                seq_len=seq,
                ckpt_every=max(steps // 6, 5),
                ckpt_mode="async",
                injector=FailureInjector([fail_at]),
                on_step=on_step,
            )
            print(f"\ncompleted: {res.steps_run} steps run, {res.restarts} restart(s) "
                  f"(recovered from the injected failure via the two-level checkpoint)")
            print(f"final loss {res.losses[-1]:.4f}; first loss {res.losses[0]:.4f}")
            st = store.tier_stats()
            print(f"checkpoint traffic to PFS tier: {st['pfs']['bytes_written']/2**20:.1f} MiB; "
                  f"async flushes: {st['store']['async_flushes']}")

            s = res.stalls
            print("\nstep stall breakdown (where the wall time went):")
            print(f"  data stall:  {s['data_stall_total_s']:7.2f}s total, "
                  f"{s['data_stall_ewma_s']*1e3:7.2f}ms/step EWMA")
            print(f"  ckpt stall:  {s['ckpt_stall_total_s']:7.2f}s total, "
                  f"{s['ckpt_stall_ewma_s']*1e3:7.2f}ms/step EWMA "
                  f"(async save critical path {s['ckpt_save_critical_s']:.2f}s)")

            ls = res.loader_stats
            slab_total = ls.get("slab_hits", 0) + ls.get("slab_misses", 0)
            win_total = ls.get("local_windows", 0) + ls.get("remote_windows", 0)
            ss = st["store"]
            mem_total = ss["mem_hits"] + ss["mem_misses"]
            print("two-level hit rates:")
            print(f"  loader slab cache: {ls.get('slab_hits', 0)}/{slab_total} hits "
                  f"({ls.get('slab_hits', 0)/max(slab_total,1):.1%}), "
                  f"{ls.get('bytes_fetched', 0)/2**20:.1f} MiB fetched via ranged reads")
            print(f"  window locality:   {ls.get('local_windows', 0)}/{win_total} "
                  f"windows on owned shards")
            print(f"  store memory tier: {ss['mem_hits']}/{mem_total} hits "
                  f"({ss['mem_hits']/max(mem_total,1):.1%}); "
                  f"{ss['range_reads']} ranged reads, "
                  f"{ss['range_bytes']/2**20:.1f} MiB ranged")

            rep = ctl.report()
            print("\nadaptive I/O controller (online Eq. 1-7 model):")
            print(f"  tier rates (EWMA):  nu={rep['nu_mbps']:.0f} MB/s mem, "
                  f"q_read={rep['q_read_mbps']:.0f} / q_write={rep['q_write_mbps']:.0f} MB/s PFS")
            print(f"  admission:          {rep['admits']} promoted, {rep['bypasses']} bypassed, "
                  f"{rep['flush_drops']} flush-dropped "
                  f"(per class: "
                  + ", ".join(f"{c}={cs['admits']}/{cs['bypasses']}"
                              for c, cs in rep['classes'].items()) + ")")
            traj = rep['readahead_trajectory']
            depths = {c: d for c, d in rep['readahead'].items()}
            print(f"  readahead depths:   {depths}"
                  + (f"; trajectory {[(c, dep) for _, c, dep in traj[-6:]]}" if traj else ""))
            print(f"  flush lanes:        {rep['flush_lanes']} now"
                  + (f"; trajectory {[n for _, n in rep['lane_trajectory'][-8:]]}"
                     if rep['lane_trajectory'] else ""))
            print(f"  in-memory fraction: measured f={rep['measured_f']:.3f} vs "
                  f"plan target f={rep['target_f']:.3f} "
                  f"(Eq. 7 demand needs f>={rep['f_required_for_demand']:.3f}; "
                  f"predicted read {rep['predicted_read_mbps']:.0f} MB/s)")


if __name__ == "__main__":
    main()
